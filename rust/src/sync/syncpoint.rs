//! Sync-points: the primitives underneath the ladder-barrier (paper §4.1,
//! Tables 3–5).
//!
//! A sync-point is a binary *gate* with an exclusive writer: `close()` and
//! `open()` are only ever called by the writer thread, `wait()` blocks the
//! (single) waiter until the gate is open. Four implementations are
//! compared, mirroring the paper's Fig 9 experiment:
//!
//! | paper             | here                                   |
//! |-------------------|----------------------------------------|
//! | pthread mutex     | `MutexGate` (Mutex<bool> + Condvar)    |
//! | pthread spinlock  | `SpinGate` (AtomicBool, spin)          |
//! | std atomic        | `AtomicGate` (paper Table 5 verbatim)  |
//! | common atomic     | `CommonAtomicLadder` (see ladder.rs)   |
//!
//! Deviation note: the paper literally locks a pthread mutex on one thread
//! and unlocks it on another, which is UB under POSIX (and impossible with
//! `std::sync::Mutex`). `MutexGate` keeps the same cost class — one
//! futex-backed syscall pair per crossing — via the idiomatic
//! `Mutex<bool>` + `Condvar` gate.
//!
//! # Spin policy
//!
//! On the paper's 20–384-core hosts, spinning waiters burn an otherwise
//! idle core. This container has **one** core, where a pure spin must be
//! preempted by the OS scheduler before the writer can run — so all
//! spinning gates take a [`SpinMode`]: `Yield` (default here) inserts
//! `thread::yield_now()` into the loop; `Pure` matches the paper's
//! busy-wait exactly and is the right choice on a many-core host.

use std::sync::atomic::{AtomicBool, AtomicU64, AtomicU8, Ordering};
use std::sync::{Condvar, Mutex};

/// Busy-wait policy for spinning gates.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SpinMode {
    /// `std::hint::spin_loop()` only — the paper's behaviour.
    Pure,
    /// Yield to the OS scheduler each iteration — required on hosts with
    /// fewer cores than threads.
    Yield,
}

impl SpinMode {
    #[inline]
    pub fn relax(self) {
        match self {
            SpinMode::Pure => std::hint::spin_loop(),
            SpinMode::Yield => std::thread::yield_now(),
        }
    }

    pub fn parse(s: &str) -> Result<Self, String> {
        match s {
            "pure" => Ok(SpinMode::Pure),
            "yield" => Ok(SpinMode::Yield),
            _ => Err(format!("unknown spin mode {s:?}; expected yield|pure")),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            SpinMode::Pure => "pure",
            SpinMode::Yield => "yield",
        }
    }
}

/// The sync-point gate interface (paper: lock / unlock / wait).
pub trait Gate: Send + Sync {
    /// Writer: close the gate (paper `lock`).
    fn close(&self);
    /// Writer: open the gate (paper `unlock`).
    fn open(&self);
    /// Waiter: block until open (paper `wait`).
    fn wait(&self);
}

/// Counts gate operations (lock/unlock/wait calls, not spin iterations) —
/// evidence for the paper's "lock economy" claim that sync operations per
/// cycle are O(workers), independent of model size.
#[derive(Debug, Default)]
pub struct OpCounter(AtomicU64);

impl OpCounter {
    #[inline]
    pub fn bump(&self) {
        self.0.fetch_add(1, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Futex-class gate: `Mutex<bool>` + `Condvar` (paper's "pthread mutex").
pub struct MutexGate {
    closed: Mutex<bool>,
    cv: Condvar,
}

impl MutexGate {
    pub fn new(closed: bool) -> Self {
        MutexGate {
            closed: Mutex::new(closed),
            cv: Condvar::new(),
        }
    }
}

impl Gate for MutexGate {
    // Poison tolerance (all three methods): a worker that panics while
    // *not* holding the gate mutex cannot corrupt the bool inside it, but
    // unwinding through a parked `wait` poisons the lock for everyone
    // else. The supervision layer (`engine::supervise`) needs the
    // surviving threads to keep making barrier progress so the failure
    // can drain through the sync-points as a structured `SimError` —
    // so poisoned locks are entered anyway instead of propagating the
    // panic.
    fn close(&self) {
        *self
            .closed
            .lock()
            .unwrap_or_else(std::sync::PoisonError::into_inner) = true;
    }

    fn open(&self) {
        *self
            .closed
            .lock()
            .unwrap_or_else(std::sync::PoisonError::into_inner) = false;
        self.cv.notify_all();
    }

    fn wait(&self) {
        let mut g = self
            .closed
            .lock()
            .unwrap_or_else(std::sync::PoisonError::into_inner);
        while *g {
            g = self
                .cv
                .wait(g)
                .unwrap_or_else(std::sync::PoisonError::into_inner);
        }
    }
}

/// Spinlock-class gate (paper's "pthread spinlock"): busy-wait on an
/// `AtomicBool`.
pub struct SpinGate {
    closed: AtomicBool,
    mode: SpinMode,
}

impl SpinGate {
    pub fn new(closed: bool, mode: SpinMode) -> Self {
        SpinGate {
            closed: AtomicBool::new(closed),
            mode,
        }
    }
}

impl Gate for SpinGate {
    fn close(&self) {
        self.closed.store(true, Ordering::Release);
    }

    fn open(&self) {
        self.closed.store(false, Ordering::Release);
    }

    fn wait(&self) {
        while self.closed.load(Ordering::Acquire) {
            self.mode.relax();
        }
    }
}

/// Paper Table 5, verbatim: `std::atomic<char> v`; lock = store(1,
/// release); unlock = store(0, release); wait = load(acquire) loop.
pub struct AtomicGate {
    v: AtomicU8,
    mode: SpinMode,
}

impl AtomicGate {
    pub fn new(closed: bool, mode: SpinMode) -> Self {
        AtomicGate {
            v: AtomicU8::new(closed as u8),
            mode,
        }
    }
}

impl Gate for AtomicGate {
    fn close(&self) {
        self.v.store(1, Ordering::Release);
    }

    fn open(&self) {
        self.v.store(0, Ordering::Release);
    }

    fn wait(&self) {
        while self.v.load(Ordering::Acquire) == 1 {
            self.mode.relax();
        }
    }
}

/// The four synchronization methods of paper Fig 9.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SyncMethod {
    /// One futex-class gate per (sync-point, worker).
    Mutex,
    /// One spinlock-class gate per (sync-point, worker).
    Spinlock,
    /// One `std::atomic` gate per (sync-point, worker) — paper Table 5.
    Atomic,
    /// Scheduler signals *all* workers through one shared atomic
    /// generation counter (the paper's winner).
    CommonAtomic,
}

impl SyncMethod {
    pub const ALL: [SyncMethod; 4] = [
        SyncMethod::Mutex,
        SyncMethod::Spinlock,
        SyncMethod::Atomic,
        SyncMethod::CommonAtomic,
    ];

    pub fn name(&self) -> &'static str {
        match self {
            SyncMethod::Mutex => "mutex",
            SyncMethod::Spinlock => "spinlock",
            SyncMethod::Atomic => "atomic",
            SyncMethod::CommonAtomic => "common-atomic",
        }
    }

    pub fn parse(s: &str) -> Result<Self, String> {
        match s {
            "mutex" => Ok(SyncMethod::Mutex),
            "spinlock" => Ok(SyncMethod::Spinlock),
            "atomic" => Ok(SyncMethod::Atomic),
            "common-atomic" | "common_atomic" | "common" => Ok(SyncMethod::CommonAtomic),
            _ => Err(format!(
                "unknown sync method {s:?}; expected mutex|spinlock|atomic|common-atomic"
            )),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    fn gate_roundtrip(g: Arc<dyn Gate>) {
        // Writer opens after a delay; waiter must block until then.
        let g2 = g.clone();
        g.close();
        let t = std::thread::spawn(move || {
            g2.wait();
            std::time::Instant::now()
        });
        std::thread::sleep(std::time::Duration::from_millis(20));
        let before_open = std::time::Instant::now();
        g.open();
        let passed_at = t.join().unwrap();
        assert!(
            passed_at >= before_open,
            "waiter passed a closed gate"
        );
        // Already-open gate: wait returns immediately.
        g.wait();
    }

    #[test]
    fn mutex_gate_blocks_until_open() {
        gate_roundtrip(Arc::new(MutexGate::new(true)));
    }

    #[test]
    fn spin_gate_blocks_until_open() {
        gate_roundtrip(Arc::new(SpinGate::new(true, SpinMode::Yield)));
    }

    #[test]
    fn atomic_gate_blocks_until_open() {
        gate_roundtrip(Arc::new(AtomicGate::new(true, SpinMode::Yield)));
    }

    #[test]
    fn method_parse_roundtrip() {
        for m in SyncMethod::ALL {
            assert_eq!(SyncMethod::parse(m.name()).unwrap(), m);
        }
        assert!(SyncMethod::parse("bogus").is_err());
    }

    #[test]
    fn op_counter_counts() {
        let c = OpCounter::default();
        c.bump();
        c.bump();
        assert_eq!(c.get(), 2);
    }
}
