//! Assembly of the paper's CPU configurations: N cores (light in-order or
//! full out-of-order), each with private L1+L2, a mesh NoC, shared banked
//! L3 with the MESI directory, and one DRAM channel per bank.
//!
//! Unit construction order groups each core's units consecutively
//! (core, L1, L2), so the `Contiguous` partition strategy maps naturally
//! to the paper's "2 simulated cores per worker" clustering.

use crate::cpu::light::LightCore;
use crate::cpu::ooo::{OooCfg, OooCore};
use crate::cpu::Trace;
use crate::engine::{Model, ModelBuilder, PortCfg};
use crate::mem::cache::CacheCfg;
use crate::mem::dir::DirBank;
use crate::mem::dram::DramChannel;
use crate::mem::l1::L1Cache;
use crate::mem::l2::L2Cache;
use crate::mem::msg::MemPacket;
use crate::noc::{Mesh, MeshCfg};
use crate::stats::counters::CounterId;

/// Which core performance model to instantiate.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CoreKind {
    /// Simple in-order core (paper §5.2, "light CPU").
    Light,
    /// Full out-of-order core (paper §5.3).
    Ooo(OooCfg),
}

#[derive(Debug, Clone)]
pub struct CpuSystemCfg {
    pub kind: CoreKind,
    /// Number of L3 banks (each with its own DRAM channel).
    pub banks: usize,
    pub l1: CacheCfg,
    pub l2: CacheCfg,
    /// Per-bank L3 slice.
    pub l3_bank: CacheCfg,
    pub dram_latency: u64,
    /// Light-core multiply latency (see `cpu::light`; rule-2 ablation).
    pub mul_latency: u64,
    /// Core→L1 port delay (L1 hit latency contribution).
    pub l1_delay: u64,
    /// L1→L2 port delay (L2 hit latency contribution).
    pub l2_delay: u64,
    pub mesh_link_delay: u64,
}

impl Default for CpuSystemCfg {
    fn default() -> Self {
        CpuSystemCfg {
            kind: CoreKind::Light,
            banks: 4,
            l1: CacheCfg::new(32 * 1024, 4),
            l2: CacheCfg::new(256 * 1024, 8),
            l3_bank: CacheCfg::new(2 * 1024 * 1024, 16),
            dram_latency: 100,
            mul_latency: crate::cpu::light::MUL_LATENCY,
            l1_delay: 1,
            l2_delay: 2,
            mesh_link_delay: 1,
        }
    }
}

/// Handles into the built system.
pub struct CpuSystemHandles {
    pub core_units: Vec<u32>,
    /// Unit ids per core group: [core, l1, l2].
    pub core_groups: Vec<[u32; 3]>,
    /// Remaining infrastructure units (banks, DRAM channels, routers).
    pub infra_units: Vec<u32>,
    pub cores_done: CounterId,
    pub num_cores: usize,
}

impl CpuSystemHandles {
    /// The paper's clustering (§5.2): simulated cores evenly distributed
    /// among worker threads — core group c goes to cluster c mod W, with
    /// each core's private L1/L2 kept on its core's cluster and the shared
    /// infrastructure (L3 banks, DRAM, routers) dealt round-robin.
    pub fn partition(&self, workers: usize) -> Vec<Vec<u32>> {
        let workers = workers.max(1).min(self.core_groups.len().max(1));
        let mut p = vec![Vec::new(); workers];
        for (c, group) in self.core_groups.iter().enumerate() {
            p[c % workers].extend_from_slice(group);
        }
        for (i, &u) in self.infra_units.iter().enumerate() {
            p[i % workers].push(u);
        }
        p
    }
}

/// Build a full CPU system for the given per-core traces.
pub fn build_cpu_system(traces: Vec<Trace>, cfg: &CpuSystemCfg) -> (Model, CpuSystemHandles) {
    let cores = traces.len();
    assert!(cores >= 1 && cores <= 64);
    let mut mb = ModelBuilder::new();
    let cores_done = mb.counter("cores_done");

    // Reserve per-core units (consecutively per core).
    let mut core_ids = Vec::with_capacity(cores);
    let mut l1_ids = Vec::with_capacity(cores);
    let mut l2_ids = Vec::with_capacity(cores);
    for c in 0..cores {
        core_ids.push(mb.reserve_unit(&format!("core{c}")));
        l1_ids.push(mb.reserve_unit(&format!("l1_{c}")));
        l2_ids.push(mb.reserve_unit(&format!("l2_{c}")));
    }
    let bank_ids: Vec<u32> = (0..cfg.banks)
        .map(|b| mb.reserve_unit(&format!("l3bank{b}")))
        .collect();
    let dram_ids: Vec<u32> = (0..cfg.banks)
        .map(|b| mb.reserve_unit(&format!("dram{b}")))
        .collect();

    // Mesh sized to fit cores + banks.
    let nodes = cores + cfg.banks;
    let width = (nodes as f64).sqrt().ceil() as u32;
    let height = (nodes as u32).div_ceil(width);
    let mut mesh = Mesh::build(
        &mut mb,
        MeshCfg {
            width,
            height,
            link_capacity: 4,
            link_delay: cfg.mesh_link_delay,
            local_capacity: 4,
        },
    );
    // Core c's L2 attaches at node c; bank b at node cores + b.
    let core_nodes: Vec<u32> = (0..cores as u32).collect();
    let bank_nodes: Vec<u32> = (0..cfg.banks as u32).map(|b| cores as u32 + b).collect();

    for c in 0..cores {
        // core ↔ L1: the hottest links in the system — weight 4 tells the
        // locality partitioner to never split a core from its L1.
        let (core_to_l1, l1_from_core) =
            mb.link_weighted::<MemPacket>(core_ids[c], l1_ids[c], PortCfg::new(4, cfg.l1_delay), 4);
        let (l1_to_core, core_from_l1) =
            mb.link_weighted::<MemPacket>(l1_ids[c], core_ids[c], PortCfg::new(4, cfg.l1_delay), 4);
        // L1 ↔ L2 (weight 3: private hierarchy stays together)
        let (l1_to_l2, l2_from_l1) =
            mb.link_weighted::<MemPacket>(l1_ids[c], l2_ids[c], PortCfg::new(4, cfg.l2_delay), 3);
        let (l2_to_l1, l1_from_l2) =
            mb.link_weighted::<MemPacket>(l2_ids[c], l1_ids[c], PortCfg::new(4, cfg.l2_delay), 3);
        // L2 ↔ NoC
        let (l2_to_net, l2_from_net) = mesh.attach::<MemPacket>(&mut mb, core_nodes[c], l2_ids[c]);

        match cfg.kind {
            CoreKind::Light => {
                let mut core = LightCore::new(
                    c as u32,
                    traces[c].ops.clone(),
                    core_to_l1,
                    core_from_l1,
                    cores_done,
                );
                core.mul_latency = cfg.mul_latency;
                mb.install(core_ids[c], Box::new(core));
            }
            CoreKind::Ooo(ooo_cfg) => {
                mb.install(
                    core_ids[c],
                    Box::new(OooCore::new(
                        c as u32,
                        traces[c].ops.clone(),
                        ooo_cfg,
                        core_to_l1,
                        core_from_l1,
                        cores_done,
                    )),
                );
            }
        }
        mb.install(
            l1_ids[c],
            Box::new(L1Cache::new(
                c as u32,
                cfg.l1,
                l1_from_core,
                l1_to_core,
                l1_to_l2,
                l1_from_l2,
            )),
        );
        mb.install(
            l2_ids[c],
            Box::new(L2Cache::new(
                c as u32,
                core_nodes[c],
                bank_nodes.clone(),
                cfg.l2,
                l2_from_l1,
                l2_to_l1,
                l2_to_net,
                l2_from_net,
            )),
        );
    }

    for b in 0..cfg.banks {
        let (bank_to_net, bank_from_net) =
            mesh.attach::<MemPacket>(&mut mb, bank_nodes[b], bank_ids[b]);
        let (bank_to_dram, dram_from_bank) =
            mb.link_weighted::<MemPacket>(bank_ids[b], dram_ids[b], PortCfg::new(8, 1), 3);
        let (dram_to_bank, bank_from_dram) =
            mb.link_weighted::<MemPacket>(dram_ids[b], bank_ids[b], PortCfg::new(8, 1), 3);
        mb.install(
            bank_ids[b],
            Box::new(DirBank::new(
                b as u32,
                bank_nodes[b],
                core_nodes.clone(),
                cfg.l3_bank,
                bank_from_net,
                bank_to_net,
                bank_to_dram,
                bank_from_dram,
            )),
        );
        mb.install(
            dram_ids[b],
            Box::new(DramChannel::new(
                b as u32,
                dram_from_bank,
                dram_to_bank,
                cfg.dram_latency,
                1,
            )),
        );
    }

    let router_ids = mesh.router_ids.clone();
    mesh.finish(&mut mb);
    let model = mb.build().expect("cpu system wiring");
    let core_groups: Vec<[u32; 3]> = (0..cores)
        .map(|c| [core_ids[c], l1_ids[c], l2_ids[c]])
        .collect();
    let mut infra_units: Vec<u32> = Vec::new();
    infra_units.extend(&bank_ids);
    infra_units.extend(&dram_ids);
    infra_units.extend(&router_ids);
    (
        model,
        CpuSystemHandles {
            core_units: core_ids,
            core_groups,
            infra_units,
            cores_done,
            num_cores: cores,
        },
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cpu::isa::{OpClass, TraceOp, NO_REG};
    use crate::engine::{RunOpts, Stop};

    fn ld(addr: u64) -> TraceOp {
        TraceOp::new(OpClass::Load, 1, 2, NO_REG, addr, 0, false)
    }

    fn st(addr: u64) -> TraceOp {
        TraceOp::new(OpClass::Store, NO_REG, 1, 2, addr, 0, false)
    }

    fn alu() -> TraceOp {
        TraceOp::new(OpClass::Alu, 1, 1, 2, 0, 0, false)
    }

    fn run_traces(traces: Vec<Trace>) -> crate::stats::RunStats {
        let (mut model, h) = build_cpu_system(traces, &CpuSystemCfg::default());
        model.run_serial(RunOpts::with_stop(Stop::CounterAtLeast {
            counter: h.cores_done,
            target: h.num_cores as u64,
            max_cycles: 200_000,
        }))
    }

    #[test]
    fn single_core_load_hits_after_fill() {
        // Two loads to the same line: one L1 miss then one hit.
        let t = Trace {
            ops: vec![ld(0x1000), ld(0x1008), alu()],
        };
        let stats = run_traces(vec![t]);
        assert_eq!(stats.counters.get("cores_done"), 1);
        assert_eq!(stats.counters.get("core.retired"), 3);
        assert_eq!(stats.counters.get("l1.misses"), 1);
        assert_eq!(stats.counters.get("l1.hits"), 1);
        assert_eq!(stats.counters.get("dir.gets"), 1);
        assert_eq!(stats.counters.get("dram.reads"), 1);
        // Sanity on latency: one full miss is ~dram + hops, well under 1k.
        assert!(stats.cycles > 100 && stats.cycles < 1_000, "{}", stats.cycles);
    }

    #[test]
    fn store_then_load_same_core() {
        let t = Trace {
            ops: vec![st(0x2000), ld(0x2000)],
        };
        let stats = run_traces(vec![t]);
        assert_eq!(stats.counters.get("cores_done"), 1);
        // Store triggers GetM; load then misses L1 (write-through,
        // no-allocate) but hits M in L2 — no second directory request.
        assert_eq!(stats.counters.get("dir.getm"), 1);
        assert_eq!(stats.counters.get("dir.gets"), 0);
    }

    #[test]
    fn read_sharing_two_cores() {
        // Both cores read the same line: GetS x2, second served from L3
        // (or via owner recall), no invalidations.
        let t0 = Trace { ops: vec![ld(0x3000)] };
        let t1 = Trace { ops: vec![ld(0x3000)] };
        let stats = run_traces(vec![t0, t1]);
        assert_eq!(stats.counters.get("cores_done"), 2);
        assert_eq!(stats.counters.get("dir.gets"), 2);
        assert_eq!(stats.counters.get("dram.reads"), 1, "one fetch, then share");
        assert_eq!(stats.counters.get("dir.invs_sent"), 0);
    }

    #[test]
    fn write_invalidates_reader() {
        // Core 0 reads a line; core 1 writes it (many ALU ops later so the
        // read settles first). The write must recall/invalidate core 0.
        let mut ops0 = vec![ld(0x4000)];
        ops0.extend(std::iter::repeat(alu()).take(5));
        let mut ops1: Vec<TraceOp> = std::iter::repeat(alu()).take(400).collect();
        ops1.push(st(0x4000));
        let stats = run_traces(vec![Trace { ops: ops0 }, Trace { ops: ops1 }]);
        assert_eq!(stats.counters.get("cores_done"), 2);
        assert_eq!(stats.counters.get("dir.getm"), 1);
        // Core 0 held the line (E or S): the GetM either forwards
        // (owner recall) or invalidates (sharer).
        let recalls =
            stats.counters.get("dir.fwds_sent") + stats.counters.get("dir.invs_sent");
        assert!(recalls >= 1, "writer must recall reader's copy");
    }

    #[test]
    fn parallel_matches_serial_cpu_system() {
        use crate::sched::{partition, PartitionStrategy};
        use crate::sync::{run_ladder, ParallelOpts, SyncMethod};
        let mk_traces = || {
            (0..4)
                .map(|c| Trace {
                    ops: (0..50)
                        .map(|i| {
                            if i % 3 == 0 {
                                ld(0x1000 + ((c * 64 + i * 8) as u64 % 4096))
                            } else if i % 7 == 0 {
                                st(0x8000 + (i as u64 % 512))
                            } else {
                                alu()
                            }
                        })
                        .collect(),
                })
                .collect::<Vec<_>>()
        };
        let stop = |h: &CpuSystemHandles| Stop::CounterAtLeast {
            counter: h.cores_done,
            target: 4,
            max_cycles: 100_000,
        };
        let (mut serial, h) = build_cpu_system(mk_traces(), &CpuSystemCfg::default());
        let s = serial.run_serial(RunOpts::with_stop(stop(&h)).fingerprinted());
        assert_eq!(s.counters.get("cores_done"), 4);
        for workers in [2, 3] {
            let (mut par, h) = build_cpu_system(mk_traces(), &CpuSystemCfg::default());
            let part = partition(&par, workers, PartitionStrategy::Contiguous);
            let p = run_ladder(
                &mut par,
                &part,
                &ParallelOpts::new(
                    SyncMethod::CommonAtomic,
                    RunOpts::with_stop(stop(&h)).fingerprinted(),
                ),
            );
            assert_eq!(
                p.fingerprint, s.fingerprint,
                "parallel ({workers}w) must match serial"
            );
            assert_eq!(p.cycles, s.cycles, "cycle counts must match");
        }
    }
}
