//! Ready-made model assemblies for the paper's evaluated configurations:
//! the light-CPU multicore (§5.2), the out-of-order multicore (§5.3), and
//! the data-center fabric (§5.4, in `crate::dc`).

pub mod cpu_system;

pub use cpu_system::{build_cpu_system, CoreKind, CpuSystemCfg, CpuSystemHandles};
