//! Minimal command-line argument parser (offline substitute for `clap`).
//!
//! Supports `--key value`, `--key=value`, boolean `--flag`, and positional
//! arguments. Each subcommand of the `scalesim` binary declares the options
//! it understands; unknown options are an error so typos fail loudly.
//!
//! [`Cmd`] is the merged view every subcommand actually wants: CLI
//! arguments layered over an optional `--config file.toml`
//! ([`super::config::Config`]), with typed accessors that fall back
//! args → file → default.

use super::config::Config;
use std::collections::BTreeMap;

#[derive(Debug, Clone, Default)]
pub struct Args {
    opts: BTreeMap<String, String>,
    flags: Vec<String>,
    positional: Vec<String>,
    /// Option names the command declared; used for error reporting.
    known: Vec<String>,
}

impl Args {
    /// Parse `argv` (without the program/subcommand names).
    ///
    /// `known_opts` are options that take a value, `known_flags` are
    /// booleans. Anything else is positional.
    pub fn parse(
        argv: &[String],
        known_opts: &[&str],
        known_flags: &[&str],
    ) -> Result<Self, String> {
        let mut a = Args::default();
        a.known = known_opts
            .iter()
            .chain(known_flags.iter())
            .map(|s| s.to_string())
            .collect();
        let mut i = 0;
        while i < argv.len() {
            let tok = &argv[i];
            if let Some(stripped) = tok.strip_prefix("--") {
                let (name, inline_val) = match stripped.split_once('=') {
                    Some((n, v)) => (n.to_string(), Some(v.to_string())),
                    None => (stripped.to_string(), None),
                };
                if known_flags.contains(&name.as_str()) {
                    if inline_val.is_some() {
                        return Err(format!("flag --{name} does not take a value"));
                    }
                    a.flags.push(name);
                } else if known_opts.contains(&name.as_str()) {
                    let val = match inline_val {
                        Some(v) => v,
                        None => {
                            i += 1;
                            argv.get(i)
                                .cloned()
                                .ok_or_else(|| format!("--{name} requires a value"))?
                        }
                    };
                    a.opts.insert(name, val);
                } else {
                    return Err(format!(
                        "unknown option --{name}; known: {}",
                        a.known.join(", ")
                    ));
                }
            } else {
                a.positional.push(tok.clone());
            }
            i += 1;
        }
        Ok(a)
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.opts.get(name).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    pub fn get_u64(&self, name: &str, default: u64) -> Result<u64, String> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => parse_u64(v).map_err(|e| format!("--{name}: {e}")),
        }
    }

    pub fn get_usize(&self, name: &str, default: usize) -> Result<usize, String> {
        self.get_u64(name, default as u64).map(|v| v as usize)
    }

    pub fn get_f64(&self, name: &str, default: f64) -> Result<f64, String> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse::<f64>()
                .map_err(|e| format!("--{name}: {e}")),
        }
    }

    pub fn positional(&self) -> &[String] {
        &self.positional
    }
}

/// A subcommand's merged option view: CLI arguments override values from
/// the `--config` file (which every subcommand accepts implicitly).
#[derive(Debug, Clone, Default)]
pub struct Cmd {
    args: Args,
    file: Config,
}

impl Cmd {
    /// Parse `argv` with the subcommand's declared options/flags. The
    /// `config` option is added automatically; when present, the file is
    /// loaded so its values back the typed accessors.
    pub fn parse(
        argv: &[String],
        known_opts: &[&str],
        known_flags: &[&str],
    ) -> Result<Self, String> {
        let mut opts: Vec<&str> = known_opts.to_vec();
        if !opts.contains(&"config") {
            opts.push("config");
        }
        let args = Args::parse(argv, &opts, known_flags)?;
        let file = match args.get("config") {
            Some(path) => Config::from_file(std::path::Path::new(path))?,
            None => Config::new(),
        };
        Ok(Cmd { args, file })
    }

    /// CLI value if given, else the config-file value.
    pub fn get(&self, name: &str) -> Option<&str> {
        self.args.get(name).or_else(|| self.file.get(name))
    }

    /// CLI value only — no config-file fallback. For options whose file
    /// form is consumed elsewhere (e.g. scenario keys) and must not be
    /// re-applied as a CLI override.
    pub fn from_cli(&self, name: &str) -> Option<&str> {
        self.args.get(name)
    }

    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    pub fn get_u64(&self, name: &str, default: u64) -> Result<u64, String> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => parse_u64(v).map_err(|e| format!("--{name}: {e}")),
        }
    }

    pub fn get_usize(&self, name: &str, default: usize) -> Result<usize, String> {
        self.get_u64(name, default as u64).map(|v| v as usize)
    }

    pub fn get_f64(&self, name: &str, default: f64) -> Result<f64, String> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v.parse::<f64>().map_err(|e| format!("--{name}: {e}")),
        }
    }

    /// Comma-separated list of counts (`--workers 1,2,4`), args over file
    /// over `default`.
    pub fn get_list(&self, name: &str, default: &str) -> Result<Vec<usize>, String> {
        parse_usize_list(self.get_or(name, default)).map_err(|e| format!("--{name}: {e}"))
    }

    /// True when the flag was passed on the CLI or set truthy in the file.
    pub fn flag(&self, name: &str) -> Result<bool, String> {
        if self.args.flag(name) {
            return Ok(true);
        }
        self.file.get_bool(name, false)
    }

    /// The underlying config file contents (for scenario key passthrough).
    pub fn file_config(&self) -> &Config {
        &self.file
    }

    pub fn positional(&self) -> &[String] {
        self.args.positional()
    }
}

/// Parse a comma-separated list of counts (`1,2,4,8`), with the same
/// suffix/underscore liberties as [`parse_u64`].
pub fn parse_usize_list(s: &str) -> Result<Vec<usize>, String> {
    s.split(',')
        .map(|t| parse_u64(t.trim()).map(|v| v as usize))
        .collect()
}

/// Parse an f64 allowing a trailing `%` (e.g. `5%` → `0.05`) — the
/// natural way to write thresholds like the repartitioning hysteresis.
pub fn parse_f64(s: &str) -> Result<f64, String> {
    let (body, scale) = match s.strip_suffix('%') {
        Some(b) => (b.trim(), 0.01),
        None => (s, 1.0),
    };
    body.parse::<f64>()
        .map(|v| v * scale)
        .map_err(|e| format!("bad number {s:?}: {e}"))
}

/// Parse a u64 allowing `_` separators and `k`/`m`/`g` suffixes
/// (e.g. `128k`, `3m`, `1_000_000`).
pub fn parse_u64(s: &str) -> Result<u64, String> {
    let s = s.replace('_', "");
    let (body, mult) = match s.chars().last() {
        Some('k') | Some('K') => (&s[..s.len() - 1], 1_000u64),
        Some('m') | Some('M') => (&s[..s.len() - 1], 1_000_000u64),
        Some('g') | Some('G') => (&s[..s.len() - 1], 1_000_000_000u64),
        _ => (s.as_str(), 1u64),
    };
    body.parse::<u64>()
        .map(|v| v * mult)
        .map_err(|e| format!("bad number {s:?}: {e}"))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sv(v: &[&str]) -> Vec<String> {
        v.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn parses_opts_flags_positional() {
        let a = Args::parse(
            &sv(&["--cycles", "100", "--verbose", "--out=x.txt", "posarg"]),
            &["cycles", "out"],
            &["verbose"],
        )
        .unwrap();
        assert_eq!(a.get_u64("cycles", 0).unwrap(), 100);
        assert!(a.flag("verbose"));
        assert_eq!(a.get("out"), Some("x.txt"));
        assert_eq!(a.positional(), &["posarg".to_string()]);
    }

    #[test]
    fn unknown_option_is_error() {
        assert!(Args::parse(&sv(&["--nope", "1"]), &["cycles"], &[]).is_err());
    }

    #[test]
    fn missing_value_is_error() {
        assert!(Args::parse(&sv(&["--cycles"]), &["cycles"], &[]).is_err());
    }

    #[test]
    fn suffixes() {
        assert_eq!(parse_u64("128k").unwrap(), 128_000);
        assert_eq!(parse_u64("3m").unwrap(), 3_000_000);
        assert_eq!(parse_u64("1_000").unwrap(), 1_000);
        assert!(parse_u64("xx").is_err());
    }

    #[test]
    fn f64_percent_suffix() {
        assert!((parse_f64("0.25").unwrap() - 0.25).abs() < 1e-12);
        assert!((parse_f64("5%").unwrap() - 0.05).abs() < 1e-12);
        assert!((parse_f64("12.5 %").unwrap() - 0.125).abs() < 1e-12);
        assert!(parse_f64("pct").is_err());
    }

    #[test]
    fn defaults_apply() {
        let a = Args::parse(&sv(&[]), &["cycles"], &[]).unwrap();
        assert_eq!(a.get_u64("cycles", 77).unwrap(), 77);
        assert_eq!(a.get_or("cycles", "d"), "d");
    }

    #[test]
    fn usize_list_parses() {
        assert_eq!(parse_usize_list("1, 2,4").unwrap(), vec![1, 2, 4]);
        assert_eq!(parse_usize_list("2k").unwrap(), vec![2000]);
        assert!(parse_usize_list("1,x").is_err());
    }

    #[test]
    fn cmd_merges_cli_over_file() {
        let dir = std::env::temp_dir().join("scalesim_cli_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("cmd.toml");
        std::fs::write(&path, "cycles = 100\nworkers = \"1,2\"\nsmoke = true\n").unwrap();
        let argv = sv(&[
            "--cycles",
            "200",
            "--config",
            path.to_str().unwrap(),
        ]);
        let c = Cmd::parse(&argv, &["cycles", "workers"], &["smoke"]).unwrap();
        // CLI wins over file; file backs what the CLI omits.
        assert_eq!(c.get_u64("cycles", 0).unwrap(), 200);
        assert_eq!(c.get_list("workers", "9").unwrap(), vec![1, 2]);
        assert!(c.flag("smoke").unwrap(), "file-set flag is honoured");
        assert_eq!(c.get_or("missing", "d"), "d");
    }

    #[test]
    fn cmd_without_config_uses_defaults() {
        let c = Cmd::parse(&sv(&[]), &["cycles"], &["v"]).unwrap();
        assert_eq!(c.get_u64("cycles", 7).unwrap(), 7);
        assert!(!c.flag("v").unwrap());
        assert_eq!(c.get_list("workers", "1,2").unwrap(), vec![1, 2]);
    }
}
