//! Minimal flat-TOML config parser (offline substitute for `serde`+`toml`).
//!
//! The launcher accepts `--config file.toml` for every experiment; the file
//! holds `key = value` lines with optional `[section]` headers. Sections
//! flatten to `section.key`. Values are strings, integers, floats or bools;
//! everything is kept as a string and converted on access, mirroring the
//! CLI layer so the two can be merged (CLI overrides file).
//!
//! Parse with the standard trait: `text.parse::<Config>()`.

use std::collections::BTreeMap;
use std::path::Path;

#[derive(Debug, Clone, Default)]
pub struct Config {
    values: BTreeMap<String, String>,
}

/// Strip a `#` comment, honouring double quotes: a `#` inside a quoted
/// value is part of the value.
fn strip_comment(line: &str) -> &str {
    let mut in_quotes = false;
    for (i, ch) in line.char_indices() {
        match ch {
            '"' => in_quotes = !in_quotes,
            '#' if !in_quotes => return &line[..i],
            _ => {}
        }
    }
    line
}

/// Remove one pair of surrounding double quotes, if present.
fn unquote(v: &str) -> &str {
    if v.len() >= 2 && v.starts_with('"') && v.ends_with('"') {
        &v[1..v.len() - 1]
    } else {
        v
    }
}

impl std::str::FromStr for Config {
    type Err = String;

    /// Parse from TOML-subset text. Comments start with `#` (outside
    /// quotes).
    fn from_str(text: &str) -> Result<Self, String> {
        let mut cfg = Config::new();
        let mut section = String::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = strip_comment(raw).trim();
            if line.is_empty() {
                continue;
            }
            if let Some(sec) = line.strip_prefix('[') {
                let sec = sec
                    .strip_suffix(']')
                    .ok_or_else(|| format!("line {}: unterminated section", lineno + 1))?;
                section = sec.trim().to_string();
                continue;
            }
            let (k, v) = line
                .split_once('=')
                .ok_or_else(|| format!("line {}: expected key = value", lineno + 1))?;
            let key = if section.is_empty() {
                k.trim().to_string()
            } else {
                format!("{}.{}", section, k.trim())
            };
            let val = unquote(v.trim()).to_string();
            cfg.values.insert(key, val);
        }
        Ok(cfg)
    }
}

impl Config {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn from_file(path: &Path) -> Result<Self, String> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| format!("read {}: {e}", path.display()))?;
        text.parse()
    }

    pub fn set(&mut self, key: &str, val: impl ToString) {
        self.values.insert(key.to_string(), val.to_string());
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.values.get(key).map(|s| s.as_str())
    }

    pub fn get_u64(&self, key: &str, default: u64) -> Result<u64, String> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => super::cli::parse_u64(v).map_err(|e| format!("{key}: {e}")),
        }
    }

    pub fn get_usize(&self, key: &str, default: usize) -> Result<usize, String> {
        self.get_u64(key, default as u64).map(|v| v as usize)
    }

    pub fn get_f64(&self, key: &str, default: f64) -> Result<f64, String> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v.parse::<f64>().map_err(|e| format!("{key}: {e}")),
        }
    }

    pub fn get_bool(&self, key: &str, default: bool) -> Result<bool, String> {
        match self.get(key) {
            None => Ok(default),
            Some("true") | Some("1") | Some("yes") => Ok(true),
            Some("false") | Some("0") | Some("no") => Ok(false),
            Some(v) => Err(format!("{key}: bad bool {v:?}")),
        }
    }

    /// Merge `other` on top of `self` (other wins).
    pub fn overlay(&mut self, other: &Config) {
        for (k, v) in &other.values {
            self.values.insert(k.clone(), v.clone());
        }
    }

    pub fn keys(&self) -> impl Iterator<Item = &str> {
        self.values.keys().map(|s| s.as_str())
    }

    /// All `(key, value)` pairs in deterministic (sorted) order — the
    /// checkpoint meta block records these so `--restore` can rebuild the
    /// exact session without `--scenario`/`--set`.
    pub fn pairs(&self) -> Vec<(String, String)> {
        self.values
            .iter()
            .map(|(k, v)| (k.clone(), v.clone()))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(text: &str) -> Result<Config, String> {
        text.parse()
    }

    #[test]
    fn parses_sections_and_types() {
        let cfg = parse(
            r#"
            # top comment
            seed = 42
            [model]
            cores = 32        # trailing comment
            skew = 0.9
            name = "oltp"
            coherent = true
            "#,
        )
        .unwrap();
        assert_eq!(cfg.get_u64("seed", 0).unwrap(), 42);
        assert_eq!(cfg.get_usize("model.cores", 0).unwrap(), 32);
        assert!((cfg.get_f64("model.skew", 0.0).unwrap() - 0.9).abs() < 1e-12);
        assert_eq!(cfg.get("model.name"), Some("oltp"));
        assert!(cfg.get_bool("model.coherent", false).unwrap());
    }

    #[test]
    fn overlay_wins() {
        let mut a = parse("x = 1\ny = 2").unwrap();
        let b = parse("y = 3").unwrap();
        a.overlay(&b);
        assert_eq!(a.get_u64("x", 0).unwrap(), 1);
        assert_eq!(a.get_u64("y", 0).unwrap(), 3);
    }

    #[test]
    fn errors_are_reported() {
        assert!(parse("[bad").is_err());
        assert!(parse("novalue").is_err());
        let cfg = parse("z = zz").unwrap();
        assert!(cfg.get_u64("z", 0).is_err());
        assert!(cfg.get_bool("z", false).is_err());
    }

    #[test]
    fn hash_inside_quotes_is_not_a_comment() {
        let cfg = parse("label = \"a#b\"  # real comment\n").unwrap();
        assert_eq!(cfg.get("label"), Some("a#b"));
        // Unquoted values still end at the comment marker.
        let cfg = parse("label = ab # comment").unwrap();
        assert_eq!(cfg.get("label"), Some("ab"));
    }

    #[test]
    fn unquoting_removes_exactly_one_pair() {
        let cfg = parse("a = \"\"\nb = \"\"quoted\"\"\nc = \"").unwrap();
        assert_eq!(cfg.get("a"), Some(""));
        // Only the outer pair is stripped.
        assert_eq!(cfg.get("b"), Some("\"quoted\""));
        // A lone quote is preserved verbatim.
        assert_eq!(cfg.get("c"), Some("\""));
    }

    #[test]
    fn malformed_numbers_and_bools_error_with_key() {
        let cfg = parse("n = 12x\nb = tru").unwrap();
        let e = cfg.get_u64("n", 0).unwrap_err();
        assert!(e.contains("n:"), "{e}");
        let e = cfg.get_bool("b", false).unwrap_err();
        assert!(e.contains("b:"), "{e}");
        let e = cfg.get_f64("n", 0.0).unwrap_err();
        assert!(e.contains("n:"), "{e}");
    }

    #[test]
    fn fromstr_trait_is_implemented() {
        // `str::parse` goes through `std::str::FromStr` — the clippy
        // `should_implement_trait` shape.
        let cfg: Config = "k = v".parse().unwrap();
        assert_eq!(cfg.get("k"), Some("v"));
    }
}
