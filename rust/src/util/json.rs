//! Shared helpers for the crate's hand-rolled JSON emitters.
//!
//! The crate is dependency-free, so every JSON record — `RunReport`,
//! BENCH rows, sweep JSONL, trace export — is assembled with `format!`.
//! That is fine for numbers but has two classic failure modes this
//! module centralizes the fix for:
//!
//! - **Unescaped strings**: a scenario or strategy name containing `"`
//!   or `\` corrupts the record. [`json_escape`] (hoisted from the sweep
//!   writer, which always escaped) is now the single implementation all
//!   emitters share.
//! - **Non-finite floats**: `format!("{:.1}", f64::INFINITY)` prints
//!   `inf`, which is not JSON. [`finite`] clamps `inf`/`NaN` rates to
//!   0.0 at the emitter so degenerate runs (zero cycles, zero wall)
//!   still produce parseable records.

/// Escape a string for embedding inside a JSON string literal (quotes
/// not included). Handles `"`, `\`, and control characters.
pub fn json_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

/// A complete JSON string literal: `json_str(r#"a"b"#)` → `"a\"b"`.
pub fn json_str(s: &str) -> String {
    format!("\"{}\"", json_escape(s))
}

/// Clamp a rate/ratio to a finite value for `format!`-based emitters:
/// `inf` and `NaN` (zero-cycle or zero-wall runs) become 0.0, which is
/// both valid JSON and the honest value for a run that measured nothing.
pub fn finite(x: f64) -> f64 {
    if x.is_finite() {
        x
    } else {
        0.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn escapes_quotes_backslashes_and_controls() {
        assert_eq!(json_escape(r#"a"b\c"#), r#"a\"b\\c"#);
        assert_eq!(json_escape("x\ny\t\u{1}"), "x\\ny\\t\\u0001");
        assert_eq!(json_str(r#"we"ird"#), r#""we\"ird""#);
    }

    #[test]
    fn finite_clamps_only_non_finite() {
        assert_eq!(finite(1.5), 1.5);
        assert_eq!(finite(0.0), 0.0);
        assert_eq!(finite(f64::INFINITY), 0.0);
        assert_eq!(finite(f64::NEG_INFINITY), 0.0);
        assert_eq!(finite(f64::NAN), 0.0);
    }
}
