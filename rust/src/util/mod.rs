//! Small self-contained utilities: deterministic RNG, CLI parsing, config.
//!
//! This build is fully offline; instead of pulling `rand`, `clap`, `serde`
//! etc., we carry minimal hand-rolled equivalents tailored to what the
//! simulator actually needs.

pub mod cli;
pub mod config;
pub mod json;
pub mod rng;
