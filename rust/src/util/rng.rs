//! Deterministic, splittable pseudo-random number generation.
//!
//! ScaleSimulator's correctness story requires that a parallel run be
//! bit-identical to a serial run ("as if it is simulated in a serial
//! manner", paper §3.1). Any randomness consumed by a unit therefore has to
//! come from a stream owned by that unit and seeded only by stable
//! identifiers (unit id, global seed) — never by execution order.
//!
//! `SplitMix64` is used as a seeder/mixer; `Xoshiro256**` is the workhorse
//! generator. Both are tiny, fast, and reproduce identically across
//! platforms, which keeps golden-value tests stable.

/// SplitMix64 — used to expand a single `u64` seed into stream states.
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    /// Raw generator state (checkpoint/restore).
    pub fn state(&self) -> u64 {
        self.state
    }

    /// Rebuild a generator mid-stream from a saved state.
    pub fn from_state(state: u64) -> Self {
        Self { state }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }
}

/// Xoshiro256** — the per-unit / per-workload generator.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Seed a generator from a global seed and a stream id. Distinct
    /// `(seed, stream)` pairs give statistically independent streams.
    pub fn from_seed_stream(seed: u64, stream: u64) -> Self {
        let mut sm = SplitMix64::new(seed ^ stream.wrapping_mul(0xA076_1D64_78BD_642F));
        let mut s = [0u64; 4];
        for v in &mut s {
            *v = sm.next_u64();
        }
        // Xoshiro must not be seeded with all zeros.
        if s == [0, 0, 0, 0] {
            s[0] = 0x9E37_79B9_7F4A_7C15;
        }
        Self { s }
    }

    pub fn new(seed: u64) -> Self {
        Self::from_seed_stream(seed, 0)
    }

    /// Raw generator state (checkpoint/restore).
    pub fn state(&self) -> [u64; 4] {
        self.s
    }

    /// Rebuild a generator mid-stream from a saved state.
    pub fn from_state(s: [u64; 4]) -> Self {
        Self { s }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform in `[0, bound)`; bound must be non-zero.
    /// Lemire's multiply-shift rejection method (unbiased).
    #[inline]
    pub fn gen_range(&mut self, bound: u64) -> u64 {
        debug_assert!(bound > 0);
        loop {
            let x = self.next_u64();
            let m = (x as u128).wrapping_mul(bound as u128);
            let lo = m as u64;
            if lo >= bound || lo >= (u64::MAX - bound + 1) % bound {
                return (m >> 64) as u64;
            }
        }
    }

    /// Uniform float in `[0, 1)`.
    #[inline]
    pub fn gen_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Bernoulli trial with probability `p`.
    #[inline]
    pub fn gen_bool(&mut self, p: f64) -> bool {
        self.gen_f64() < p
    }

    /// Geometric-ish bounded pareto used by workload generators to get
    /// skewed (hot/cold) access patterns. Returns value in `[0, n)` with
    /// Zipf-like skew `theta` in (0, 1]; theta → 0 is uniform-ish.
    pub fn gen_zipf(&mut self, n: u64, theta: f64) -> u64 {
        // Approximate Zipf via inverse-power transform; exact Zipf CDF
        // inversion is too slow for the hot path and the workloads only
        // need a controllable skew knob.
        let u = self.gen_f64();
        let v = u.powf(1.0 / (1.0 - theta).max(1e-9));
        let idx = (v * n as f64) as u64;
        idx.min(n - 1)
    }

    /// Sample an exponential inter-arrival time with mean `mean`.
    pub fn gen_exp(&mut self, mean: f64) -> f64 {
        let u = self.gen_f64().max(1e-12);
        -mean * u.ln()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn splitmix_known_values() {
        // Reference values from the canonical SplitMix64 with seed 1234567.
        let mut sm = SplitMix64::new(1234567);
        let a = sm.next_u64();
        let b = sm.next_u64();
        assert_ne!(a, b);
        // Determinism: same seed, same sequence.
        let mut sm2 = SplitMix64::new(1234567);
        assert_eq!(sm2.next_u64(), a);
        assert_eq!(sm2.next_u64(), b);
    }

    #[test]
    fn streams_are_independent_and_deterministic() {
        let mut r1 = Rng::from_seed_stream(42, 1);
        let mut r2 = Rng::from_seed_stream(42, 2);
        let s1: Vec<u64> = (0..8).map(|_| r1.next_u64()).collect();
        let s2: Vec<u64> = (0..8).map(|_| r2.next_u64()).collect();
        assert_ne!(s1, s2);
        let mut r1b = Rng::from_seed_stream(42, 1);
        let s1b: Vec<u64> = (0..8).map(|_| r1b.next_u64()).collect();
        assert_eq!(s1, s1b);
    }

    #[test]
    fn gen_range_is_in_bounds_and_covers() {
        let mut r = Rng::new(7);
        let mut seen = [false; 10];
        for _ in 0..10_000 {
            let v = r.gen_range(10) as usize;
            assert!(v < 10);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s), "all buckets should be hit");
    }

    #[test]
    fn gen_f64_unit_interval() {
        let mut r = Rng::new(9);
        for _ in 0..10_000 {
            let v = r.gen_f64();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn zipf_is_skewed() {
        let mut r = Rng::new(11);
        let n = 1000u64;
        let mut low = 0usize;
        for _ in 0..10_000 {
            if r.gen_zipf(n, 0.9) < n / 10 {
                low += 1;
            }
        }
        // With strong skew most of the mass is in the low decile.
        assert!(low > 5_000, "zipf skew too weak: {low}");
    }

    #[test]
    fn zipf_theta_zero_roughly_uniform() {
        let mut r = Rng::new(13);
        let n = 1000u64;
        let mut low = 0usize;
        for _ in 0..10_000 {
            if r.gen_zipf(n, 0.0) < n / 10 {
                low += 1;
            }
        }
        assert!((500..2_000).contains(&low), "uniform-ish expected: {low}");
    }

    #[test]
    fn exp_mean_close() {
        let mut r = Rng::new(17);
        let mean = 8.0;
        let sum: f64 = (0..50_000).map(|_| r.gen_exp(mean)).sum();
        let m = sum / 50_000.0;
        assert!((m - mean).abs() < 0.3, "mean {m} too far from {mean}");
    }
}
