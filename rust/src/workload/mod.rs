//! Synthetic workload generators (DESIGN.md §3 substitutions).
//!
//! The paper drives its CPU models with OLTP (SQL) and SPEC2006 through a
//! QEMU functional model. We synthesize programs in the tiny RISC ISA with
//! the same performance-relevant structure — OLTP's lock contention, index
//! walks and logging; SPEC-like loop kernels with controllable ILP and
//! locality — and execute them on the real functional model so all sharing
//! and contention is genuine.

pub mod oltp;
pub mod spec;

pub use oltp::{generate_oltp_traces, OltpCfg};
pub use spec::{generate_spec_traces, SpecKind};
