//! Synthetic OLTP workload: transactional key-value operations over shared
//! tables, with per-row spinlocks, index walks (dependent loads), and a
//! per-core append-only log — the performance-relevant skeleton of the
//! OLTP-Bench workloads the paper runs (TPC-C-style row locking and hot-key
//! contention).
//!
//! Memory layout (all 8-byte words, 64-byte rows):
//!
//! ```text
//! [locks_base ..)   lock words, one per row (own line each)
//! [rows_base ..)    row payloads, 64 B per row
//! [index_base ..)   index nodes: chains walked before touching the row
//! [log_base ..)     per-core append-only log regions
//! [txn_base ..)     per-core transaction input tables (row id, is_write)
//! ```
//!
//! The per-core program is a *loop* over its transaction input table (the
//! "client requests"), exactly like a real OLTP worker thread: stable
//! branch PCs for the spin/commit branches (so branch predictors see
//! realistic streams), data-dependent read-vs-write branches, genuine CAS
//! contention through the shared lock words.

use crate::cpu::functional::Functional;
use crate::cpu::isa::{Alu, Cond, Instr, Program};
use crate::cpu::Trace;
use crate::util::rng::Rng;

#[derive(Debug, Clone)]
pub struct OltpCfg {
    pub cores: usize,
    /// Rows in the shared table.
    pub rows: u64,
    /// Zipf skew for row selection (0 = uniform, →1 = very hot).
    pub theta: f64,
    /// Transactions per core in the generated input table.
    pub txns_per_core: u64,
    /// Fraction of transactions that write (vs read-only).
    pub write_frac: f64,
    /// Dependent index-node hops before touching the row.
    pub index_depth: u64,
    /// Words read/written in the row payload (≤ 8 = one line).
    pub row_words: u64,
    /// Instruction budget per core when running the FM.
    pub max_instrs_per_core: u64,
    pub seed: u64,
}

impl Default for OltpCfg {
    fn default() -> Self {
        OltpCfg {
            cores: 4,
            rows: 1024,
            theta: 0.6,
            txns_per_core: 64,
            write_frac: 0.5,
            index_depth: 3,
            row_words: 4,
            max_instrs_per_core: 200_000,
            seed: 0xB00C,
        }
    }
}

/// Layout constants.
const ROW_BYTES: u64 = 64;
const LOG_BYTES_PER_CORE: u64 = 64 * 1024;

pub(crate) struct Layout {
    pub locks_base: u64,
    pub rows_base: u64,
    pub index_base: u64,
    pub log_base: u64,
    pub txn_base: u64,
    pub index_nodes: u64,
    pub total: u64,
}

pub(crate) fn layout(cfg: &OltpCfg) -> Layout {
    let locks_base = 64u64;
    let rows_base = locks_base + cfg.rows * ROW_BYTES;
    let index_nodes = (cfg.rows / 4).max(16).next_power_of_two();
    let index_base = rows_base + cfg.rows * ROW_BYTES;
    let log_base = index_base + index_nodes * ROW_BYTES;
    let txn_base = log_base + cfg.cores as u64 * LOG_BYTES_PER_CORE;
    // Transaction table: 2 words (row, is_write) per txn per core.
    let total = txn_base + cfg.cores as u64 * cfg.txns_per_core * 16;
    Layout {
        locks_base,
        rows_base,
        index_base,
        log_base,
        txn_base,
        index_nodes,
        total,
    }
}

// Register conventions for generated code.
const R_T1: u8 = 1; // scratch
const R_VAL: u8 = 2;
const R_T3: u8 = 3;
const R_NODE: u8 = 4; // index-walk node id
const R_ROW: u8 = 5; // current row id
const R_ISWR: u8 = 6; // is_write flag
const R_LOCKADDR: u8 = 10;
const R_ZERO_CMP: u8 = 11; // expected value for CAS (0)
const R_ONE: u8 = 12; // lock-taken value
const R_ROWADDR: u8 = 13;
const R_IDXADDR: u8 = 14;
const R_LOGPTR: u8 = 15;
const R_TXNPTR: u8 = 16; // walks the transaction input table
const R_TXN: u8 = 20; // transaction counter
const R_NTXN: u8 = 21;

/// The per-core OLTP worker program: a loop over the transaction table.
pub fn oltp_program(core: usize, cfg: &OltpCfg) -> Program {
    let lay = layout(cfg);
    let mut p = Program::new();
    // Prologue.
    p.push(Instr::Li { rd: R_ZERO_CMP, imm: 0 });
    p.push(Instr::Li { rd: R_ONE, imm: 1 });
    p.push(Instr::Li {
        rd: R_LOGPTR,
        imm: lay.log_base + core as u64 * LOG_BYTES_PER_CORE,
    });
    p.push(Instr::Li {
        rd: R_TXNPTR,
        imm: lay.txn_base + core as u64 * cfg.txns_per_core * 16,
    });
    p.push(Instr::Li { rd: R_TXN, imm: 0 });
    p.push(Instr::Li { rd: R_NTXN, imm: cfg.txns_per_core });

    p.label("txn_loop");
    let loop_top = p.len();
    // Fetch the next transaction descriptor: row id and write flag.
    p.push(Instr::Ld { rd: R_ROW, rs1: R_TXNPTR, imm: 0 });
    p.push(Instr::Ld { rd: R_ISWR, rs1: R_TXNPTR, imm: 8 });

    // Index walk: `index_depth` dependent loads; node = row & (nodes-1),
    // then node = (node*7 + 3) & (nodes-1) per hop (B-tree-ish descent).
    p.push(Instr::OpImm {
        alu: Alu::And,
        rd: R_NODE,
        rs1: R_ROW,
        imm: (lay.index_nodes - 1) as i64,
    });
    for _ in 0..cfg.index_depth {
        // idx_addr = index_base + node*64
        p.push(Instr::OpImm { alu: Alu::Shl, rd: R_IDXADDR, rs1: R_NODE, imm: 6 });
        p.push(Instr::OpImm {
            alu: Alu::Add,
            rd: R_IDXADDR,
            rs1: R_IDXADDR,
            imm: lay.index_base as i64,
        });
        p.push(Instr::Ld { rd: R_T1, rs1: R_IDXADDR, imm: 0 });
        // key-compare flavoured ALU work + next node
        p.push(Instr::OpImm { alu: Alu::Mul, rd: R_NODE, rs1: R_NODE, imm: 7 });
        p.push(Instr::OpImm { alu: Alu::Add, rd: R_NODE, rs1: R_NODE, imm: 3 });
        p.push(Instr::OpImm {
            alu: Alu::And,
            rd: R_NODE,
            rs1: R_NODE,
            imm: (lay.index_nodes - 1) as i64,
        });
    }

    // Lock acquire: spin on CAS(lock, 0 → 1). Stable PC: the predictor
    // sees this branch once per acquire attempt.
    p.push(Instr::OpImm { alu: Alu::Shl, rd: R_LOCKADDR, rs1: R_ROW, imm: 6 });
    p.push(Instr::OpImm {
        alu: Alu::Add,
        rd: R_LOCKADDR,
        rs1: R_LOCKADDR,
        imm: lay.locks_base as i64,
    });
    p.label("acquire");
    let spin_pc = p.len();
    p.push(Instr::Cas { rd: R_T1, rs1: R_LOCKADDR, rs2: R_ZERO_CMP, rs3: R_ONE });
    let br_spin = p.push(Instr::Br { cond: Cond::Ne, rs1: R_T1, rs2: 0, off: 0 });
    p.patch_off(br_spin, spin_pc);

    // Critical section: read (and maybe write) `row_words` of the row.
    p.push(Instr::OpImm { alu: Alu::Shl, rd: R_ROWADDR, rs1: R_ROW, imm: 6 });
    p.push(Instr::OpImm {
        alu: Alu::Add,
        rd: R_ROWADDR,
        rs1: R_ROWADDR,
        imm: lay.rows_base as i64,
    });
    // Data-dependent branch: read-only transactions skip the write block.
    let br_ro = p.push(Instr::Br { cond: Cond::Eq, rs1: R_ISWR, rs2: 0, off: 0 });
    for w in 0..cfg.row_words {
        p.push(Instr::Ld { rd: R_VAL, rs1: R_ROWADDR, imm: (w * 8) as i64 });
        p.push(Instr::OpImm { alu: Alu::Add, rd: R_VAL, rs1: R_VAL, imm: 1 });
        p.push(Instr::St { rs2: R_VAL, rs1: R_ROWADDR, imm: (w * 8) as i64 });
    }
    // Log append: two sequential stores + bump pointer.
    p.push(Instr::St { rs2: R_VAL, rs1: R_LOGPTR, imm: 0 });
    p.push(Instr::St { rs2: R_ROW, rs1: R_LOGPTR, imm: 8 });
    p.push(Instr::OpImm { alu: Alu::Add, rd: R_LOGPTR, rs1: R_LOGPTR, imm: 16 });
    let after_write = p.len();
    let br_join = p.push(Instr::Jmp { off: 0 }); // writers skip the read block
    p.patch_off(br_ro, after_write + 1);
    // Read-only block.
    for w in 0..cfg.row_words {
        p.push(Instr::Ld { rd: R_T3, rs1: R_ROWADDR, imm: (w * 8) as i64 });
        p.push(Instr::Op { alu: Alu::Xor, rd: R_T3, rs1: R_T3, rs2: R_VAL });
    }
    p.patch_off(br_join, p.len());

    // Release: plain store of 0.
    p.push(Instr::St { rs2: 0, rs1: R_LOCKADDR, imm: 0 });

    // Advance to the next transaction.
    p.push(Instr::OpImm { alu: Alu::Add, rd: R_TXNPTR, rs1: R_TXNPTR, imm: 16 });
    p.push(Instr::OpImm { alu: Alu::Add, rd: R_TXN, rs1: R_TXN, imm: 1 });
    let br_loop = p.push(Instr::Br { cond: Cond::Ne, rs1: R_TXN, rs2: R_NTXN, off: 0 });
    p.patch_off(br_loop, loop_top);
    p.push(Instr::Halt);
    p
}

/// Build the functional model with programs + initialized transaction
/// tables (the "client request stream" each core consumes).
pub fn build_oltp_fm(cfg: &OltpCfg) -> Functional {
    let lay = layout(cfg);
    let programs: Vec<Program> = (0..cfg.cores).map(|c| oltp_program(c, cfg)).collect();
    let mut fm = Functional::new(programs, lay.total as usize);
    for core in 0..cfg.cores {
        let mut rng = Rng::from_seed_stream(cfg.seed, core as u64 + 1);
        let base = lay.txn_base + core as u64 * cfg.txns_per_core * 16;
        for t in 0..cfg.txns_per_core {
            let row = rng.gen_zipf(cfg.rows, cfg.theta);
            let is_write = rng.gen_bool(cfg.write_frac) as u64;
            fm.mem.store(base + t * 16, row);
            fm.mem.store(base + t * 16 + 8, is_write);
        }
    }
    fm
}

/// Generate programs, run the functional model, return per-core traces.
pub fn generate_oltp_traces(cfg: &OltpCfg) -> Vec<Trace> {
    let mut fm = build_oltp_fm(cfg);
    fm.run(cfg.max_instrs_per_core)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cpu::isa::OpClass;

    #[test]
    fn traces_are_generated_and_bounded() {
        let cfg = OltpCfg {
            cores: 2,
            txns_per_core: 8,
            ..Default::default()
        };
        let traces = generate_oltp_traces(&cfg);
        assert_eq!(traces.len(), 2);
        for t in &traces {
            assert!(!t.is_empty());
            assert!(t.len() <= cfg.max_instrs_per_core as usize);
            // Ends with Halt (all txns completed within budget).
            assert_eq!(t.ops.last().unwrap().class(), OpClass::Halt);
        }
    }

    #[test]
    fn workload_mix_is_oltp_like() {
        let traces = generate_oltp_traces(&OltpCfg {
            cores: 2,
            txns_per_core: 32,
            ..Default::default()
        });
        let all: Vec<_> = traces.iter().flat_map(|t| t.ops.iter()).collect();
        let n = all.len() as f64;
        let loads = all.iter().filter(|o| o.class() == OpClass::Load).count() as f64;
        let stores = all.iter().filter(|o| o.class() == OpClass::Store).count() as f64;
        let atomics = all.iter().filter(|o| o.class() == OpClass::Atomic).count() as f64;
        let branches = all.iter().filter(|o| o.class() == OpClass::Branch).count() as f64;
        assert!(loads / n > 0.15, "OLTP is load-heavy: {}", loads / n);
        assert!(stores / n > 0.03, "stores present: {}", stores / n);
        assert!(atomics > 0.0, "lock CAS present");
        assert!(branches / n > 0.05, "loop + spin branches: {}", branches / n);
    }

    #[test]
    fn branch_pcs_repeat_across_transactions() {
        // The worker is a loop: its branches reuse PCs, so a predictor can
        // learn them (this is what distinguishes the loop encoding from
        // naive unrolling).
        let traces = generate_oltp_traces(&OltpCfg {
            cores: 1,
            txns_per_core: 16,
            ..Default::default()
        });
        let mut pcs = std::collections::HashMap::new();
        for o in traces[0].ops.iter().filter(|o| o.class() == OpClass::Branch) {
            *pcs.entry(o.pc).or_insert(0u32) += 1;
        }
        let max_reuse = pcs.values().copied().max().unwrap();
        assert!(max_reuse >= 16, "loop branch executes once per txn: {max_reuse}");
    }

    #[test]
    fn hot_rows_are_contended() {
        // With strong skew and many cores, CAS retries must appear
        // (more atomic ops than transactions).
        let cfg = OltpCfg {
            cores: 8,
            rows: 64,
            theta: 0.95,
            txns_per_core: 32,
            ..Default::default()
        };
        let traces = generate_oltp_traces(&cfg);
        let atomics: usize = traces
            .iter()
            .map(|t| {
                t.ops
                    .iter()
                    .filter(|o| o.class() == OpClass::Atomic)
                    .count()
            })
            .sum();
        let txns = (cfg.cores as u64 * cfg.txns_per_core) as usize;
        assert!(
            atomics > txns,
            "contention should cause CAS retries: {atomics} vs {txns}"
        );
    }

    #[test]
    fn locks_serialize_all_writers_functionally() {
        // Every write txn increments row word 0 under the lock; the FM's
        // final memory must show a consistent total — i.e. no lost updates.
        let cfg = OltpCfg {
            cores: 4,
            rows: 4, // extremely hot
            theta: 0.0,
            write_frac: 1.0,
            txns_per_core: 16,
            index_depth: 1,
            row_words: 1,
            ..Default::default()
        };
        let lay = layout(&cfg);
        let mut fm = build_oltp_fm(&cfg);
        fm.run(cfg.max_instrs_per_core);
        for c in 0..cfg.cores {
            assert!(fm.halted(c), "core {c} must finish");
        }
        let mut total = 0;
        for r in 0..cfg.rows {
            total += fm.mem.load(lay.rows_base + r * ROW_BYTES);
        }
        assert_eq!(
            total,
            cfg.cores as u64 * cfg.txns_per_core,
            "row locks must prevent lost updates"
        );
    }

    #[test]
    fn deterministic_generation() {
        let cfg = OltpCfg::default();
        let a = generate_oltp_traces(&cfg);
        let b = generate_oltp_traces(&cfg);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.ops, y.ops);
        }
    }
}
