//! SPEC-like synthetic kernels: loop programs with controllable ILP, memory
//! locality and branch behaviour, standing in for the SPEC2006 suite
//! (paper §5.3 runs "spec based applications" on the OOO model).
//!
//! Four kernel archetypes cover the classic performance quadrants:
//! - `Stream` — unit-stride loads/stores, bandwidth-bound (≈ libquantum)
//! - `PointerChase` — dependent loads over a shuffled ring, latency-bound
//!   (≈ mcf)
//! - `Compute` — independent ALU/MUL chains, ILP-bound (≈ hmmer)
//! - `Branchy` — data-dependent branches, predictor-bound (≈ gobmk)

use crate::cpu::functional::Functional;
use crate::cpu::isa::{Alu, Cond, Instr, Program};
use crate::cpu::Trace;
use crate::util::rng::Rng;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SpecKind {
    Stream,
    PointerChase,
    Compute,
    Branchy,
}

impl SpecKind {
    pub const ALL: [SpecKind; 4] = [
        SpecKind::Stream,
        SpecKind::PointerChase,
        SpecKind::Compute,
        SpecKind::Branchy,
    ];

    pub fn name(&self) -> &'static str {
        match self {
            SpecKind::Stream => "stream",
            SpecKind::PointerChase => "pointer-chase",
            SpecKind::Compute => "compute",
            SpecKind::Branchy => "branchy",
        }
    }

    pub fn parse(s: &str) -> Result<Self, String> {
        match s {
            "stream" => Ok(SpecKind::Stream),
            "pointer-chase" | "chase" => Ok(SpecKind::PointerChase),
            "compute" => Ok(SpecKind::Compute),
            "branchy" => Ok(SpecKind::Branchy),
            _ => Err(format!("unknown spec kernel {s:?}")),
        }
    }
}

/// Per-core working set (bytes); each core gets a private region so SPEC
/// cores don't share (rate-mode SPEC, as used for multicore studies).
const WSET: u64 = 256 * 1024;

fn region_base(core: usize) -> u64 {
    64 + core as u64 * WSET // leave word 0 unused
}

/// Emit a loop running `iters` times around `body`.
fn emit_loop(p: &mut Program, iters: u64, body: impl FnOnce(&mut Program)) {
    const R_I: u8 = 28;
    const R_N: u8 = 29;
    p.push(Instr::Li { rd: R_I, imm: 0 });
    p.push(Instr::Li { rd: R_N, imm: iters });
    let top = p.len();
    body(p);
    p.push(Instr::OpImm {
        alu: Alu::Add,
        rd: R_I,
        rs1: R_I,
        imm: 1,
    });
    let br = p.push(Instr::Br {
        cond: Cond::Ne,
        rs1: R_I,
        rs2: R_N,
        off: 0,
    });
    p.patch_off(br, top);
}

pub fn spec_program(kind: SpecKind, core: usize, iters: u64, seed: u64) -> Program {
    let base = region_base(core);
    let mut p = Program::new();
    let mut rng = Rng::from_seed_stream(seed, (core as u64) << 8 | kind as u64);
    match kind {
        SpecKind::Stream => {
            // for i: a[i] = a[i] + b[i], unit stride, 2 loads + 1 store.
            p.push(Instr::Li { rd: 10, imm: base });
            p.push(Instr::Li { rd: 11, imm: base + WSET / 2 });
            emit_loop(&mut p, iters, |p| {
                p.push(Instr::Ld { rd: 1, rs1: 10, imm: 0 });
                p.push(Instr::Ld { rd: 2, rs1: 11, imm: 0 });
                p.push(Instr::Op {
                    alu: Alu::Add,
                    rd: 3,
                    rs1: 1,
                    rs2: 2,
                });
                p.push(Instr::St { rs2: 3, rs1: 10, imm: 0 });
                p.push(Instr::OpImm {
                    alu: Alu::Add,
                    rd: 10,
                    rs1: 10,
                    imm: 8,
                });
                p.push(Instr::OpImm {
                    alu: Alu::Add,
                    rd: 11,
                    rs1: 11,
                    imm: 8,
                });
            });
        }
        SpecKind::PointerChase => {
            // p = next[p] over a pre-built shuffled ring (the program first
            // builds the ring, then chases it — both parts are measured,
            // dominated by the chase).
            let nodes = 1024u64;
            // Build: next[i] = base + ((i*LCG) % nodes)*64 — a fixed
            // pseudo-random permutation-ish walk (not a true permutation,
            // but cycles through a large fraction of nodes).
            p.push(Instr::Li { rd: 10, imm: base });
            p.push(Instr::Li { rd: 11, imm: 0 }); // i
            p.push(Instr::Li { rd: 12, imm: nodes });
            let top = p.len();
            // target = base + ((i*2654435761) & (nodes-1)) * 64
            p.push(Instr::OpImm {
                alu: Alu::Mul,
                rd: 1,
                rs1: 11,
                imm: 0x9E3779B1,
            });
            p.push(Instr::OpImm {
                alu: Alu::And,
                rd: 1,
                rs1: 1,
                imm: (nodes - 1) as i64,
            });
            p.push(Instr::OpImm {
                alu: Alu::Shl,
                rd: 1,
                rs1: 1,
                imm: 6,
            });
            p.push(Instr::OpImm {
                alu: Alu::Add,
                rd: 1,
                rs1: 1,
                imm: base as i64,
            });
            p.push(Instr::St { rs2: 1, rs1: 10, imm: 0 });
            p.push(Instr::OpImm {
                alu: Alu::Add,
                rd: 10,
                rs1: 10,
                imm: 64,
            });
            p.push(Instr::OpImm {
                alu: Alu::Add,
                rd: 11,
                rs1: 11,
                imm: 1,
            });
            let br = p.push(Instr::Br {
                cond: Cond::Ne,
                rs1: 11,
                rs2: 12,
                off: 0,
            });
            p.patch_off(br, top);
            // Chase.
            p.push(Instr::Li { rd: 20, imm: base });
            emit_loop(&mut p, iters, |p| {
                p.push(Instr::Ld { rd: 20, rs1: 20, imm: 0 });
            });
        }
        SpecKind::Compute => {
            // 4 independent mul/xor chains — high ILP, no memory.
            for r in 1..=4u8 {
                p.push(Instr::Li {
                    rd: r,
                    imm: rng.next_u64() >> 1,
                });
            }
            emit_loop(&mut p, iters, |p| {
                for r in 1..=4u8 {
                    p.push(Instr::OpImm {
                        alu: Alu::Mul,
                        rd: r,
                        rs1: r,
                        imm: 0x5DEECE66D,
                    });
                    p.push(Instr::OpImm {
                        alu: Alu::Xor,
                        rd: r,
                        rs1: r,
                        imm: 0xB,
                    });
                }
            });
        }
        SpecKind::Branchy => {
            // Data-dependent branch on a pseudo-random value each
            // iteration; both arms do a little work.
            p.push(Instr::Li {
                rd: 5,
                imm: rng.next_u64() >> 1,
            });
            emit_loop(&mut p, iters, |p| {
                // x = x*6364136223846793005 + 1442695040888963407 (LCG)
                p.push(Instr::OpImm {
                    alu: Alu::Mul,
                    rd: 5,
                    rs1: 5,
                    imm: 0x5851F42D4C957F2Du64 as i64,
                });
                p.push(Instr::OpImm {
                    alu: Alu::Add,
                    rd: 5,
                    rs1: 5,
                    imm: 0x14057B7EF767814Fu64 as i64,
                });
                p.push(Instr::OpImm {
                    alu: Alu::Shr,
                    rd: 6,
                    rs1: 5,
                    imm: 62,
                });
                // if (x >> 62) != 0 skip the add below
                let br = p.push(Instr::Br {
                    cond: Cond::Ne,
                    rs1: 6,
                    rs2: 0,
                    off: 0,
                });
                p.push(Instr::OpImm {
                    alu: Alu::Add,
                    rd: 7,
                    rs1: 7,
                    imm: 1,
                });
                p.patch_off(br, p.len());
            });
        }
    }
    p.push(Instr::Halt);
    p
}

/// Generate traces for `cores` copies of `kind` (rate mode).
pub fn generate_spec_traces(
    kind: SpecKind,
    cores: usize,
    iters: u64,
    max_instrs: u64,
    seed: u64,
) -> Vec<Trace> {
    let programs: Vec<Program> = (0..cores)
        .map(|c| spec_program(kind, c, iters, seed))
        .collect();
    let mem = 64 + cores as u64 * WSET;
    let mut fm = Functional::new(programs, mem as usize);
    fm.run(max_instrs)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cpu::isa::OpClass;

    #[test]
    fn all_kernels_generate_and_halt() {
        for kind in SpecKind::ALL {
            let traces = generate_spec_traces(kind, 2, 100, 1_000_000, 7);
            assert_eq!(traces.len(), 2);
            for t in &traces {
                assert_eq!(
                    t.ops.last().unwrap().class(),
                    OpClass::Halt,
                    "{} must complete",
                    kind.name()
                );
            }
        }
    }

    #[test]
    fn stream_is_memory_heavy() {
        let t = &generate_spec_traces(SpecKind::Stream, 1, 200, 1_000_000, 7)[0];
        let mem = t.ops.iter().filter(|o| o.class().is_mem()).count() as f64;
        assert!(mem / t.len() as f64 > 0.3, "{}", mem / t.len() as f64);
    }

    #[test]
    fn chase_loads_are_dependent() {
        let t = &generate_spec_traces(SpecKind::PointerChase, 1, 50, 1_000_000, 7)[0];
        // In the chase phase, loads write r20 and read r20.
        let dependent = t
            .ops
            .iter()
            .filter(|o| o.class() == OpClass::Load && o.rd == 20 && o.rs1 == 20)
            .count();
        assert_eq!(dependent, 50);
    }

    #[test]
    fn compute_has_no_memory_ops_in_loop() {
        let t = &generate_spec_traces(SpecKind::Compute, 1, 100, 1_000_000, 7)[0];
        let mem = t.ops.iter().filter(|o| o.class().is_mem()).count();
        assert_eq!(mem, 0);
    }

    #[test]
    fn branchy_takes_both_arms() {
        let t = &generate_spec_traces(SpecKind::Branchy, 1, 500, 1_000_000, 7)[0];
        let branches: Vec<_> = t
            .ops
            .iter()
            .filter(|o| o.class() == OpClass::Branch)
            .collect();
        let taken = branches.iter().filter(|o| o.taken()).count();
        let ratio = taken as f64 / branches.len() as f64;
        assert!(
            (0.3..0.95).contains(&ratio),
            "mixed branch outcomes expected: {ratio}"
        );
    }
}
