//! Protocol-level tests for the MESI directory: scripted drivers stand in
//! for the cores so individual transitions can be asserted through the
//! observable counters and timing (the PM carries no data — the FM owns
//! values — so protocol correctness is about states, recalls and acks).

use scalesim::cpu::isa::{OpClass, TraceOp, NO_REG};
use scalesim::cpu::Trace;
use scalesim::engine::{RunOpts, Stop};
use scalesim::systems::{build_cpu_system, CoreKind, CpuSystemCfg};

fn ld(addr: u64) -> TraceOp {
    TraceOp::new(OpClass::Load, 1, 2, NO_REG, addr, 0, false)
}

fn st(addr: u64) -> TraceOp {
    TraceOp::new(OpClass::Store, NO_REG, 1, 2, addr, 0, false)
}

fn amo(addr: u64) -> TraceOp {
    TraceOp::new(OpClass::Atomic, 1, 2, NO_REG, addr, 0, false)
}

fn alu_n(n: usize) -> Vec<TraceOp> {
    std::iter::repeat(TraceOp::new(OpClass::Alu, 1, 1, 2, 0, 0, false))
        .take(n)
        .collect()
}

fn run(traces: Vec<Trace>) -> scalesim::stats::RunStats {
    let (mut model, h) = build_cpu_system(traces, &CpuSystemCfg::default());
    let n = h.num_cores as u64;
    model.run_serial(RunOpts::with_stop(Stop::CounterAtLeast {
        counter: h.cores_done,
        target: n,
        max_cycles: 1_000_000,
    }))
}

#[test]
fn exclusive_grant_on_sole_reader() {
    // One reader, one line: DataE (tracked as owner), no sharer traffic.
    let stats = run(vec![Trace { ops: vec![ld(0x1000)] }]);
    assert_eq!(stats.counters.get("dir.gets"), 1);
    assert_eq!(stats.counters.get("dir.invs_sent"), 0);
    assert_eq!(stats.counters.get("dir.fwds_sent"), 0);
}

#[test]
fn e_to_m_upgrade_is_silent() {
    // Load then store from the same core: E→M needs no second directory
    // transaction.
    let mut ops = vec![ld(0x2000)];
    ops.extend(alu_n(3));
    ops.push(st(0x2000));
    let stats = run(vec![Trace { ops }]);
    assert_eq!(stats.counters.get("dir.gets"), 1);
    assert_eq!(
        stats.counters.get("dir.getm"),
        0,
        "silent E→M upgrade must not hit the directory"
    );
}

#[test]
fn owner_recall_on_second_reader() {
    // Core 0 loads (E/owner); core 1 loads later → FwdWbS recall, DataS.
    let t0 = Trace { ops: vec![ld(0x3000)] };
    let mut ops1 = alu_n(400);
    ops1.push(ld(0x3000));
    let stats = run(vec![t0, Trace { ops: ops1 }]);
    assert_eq!(stats.counters.get("dir.gets"), 2);
    assert_eq!(stats.counters.get("dir.fwds_sent"), 1, "owner recalled");
    assert_eq!(stats.counters.get("dram.reads"), 1, "data served from L3");
}

#[test]
fn writer_invalidates_all_sharers() {
    // Cores 0 and 1 read; core 2 writes → 2 invalidations collected.
    let t0 = Trace { ops: vec![ld(0x4000)] };
    let mut ops1 = alu_n(200);
    ops1.push(ld(0x4000));
    let mut ops2 = alu_n(800);
    ops2.push(st(0x4000));
    let stats = run(vec![t0, Trace { ops: ops1 }, Trace { ops: ops2 }]);
    assert_eq!(stats.counters.get("dir.getm"), 1);
    // The first reader became the owner (DataE), the second a sharer via
    // recall — the writer's GetM therefore recalls the owner or
    // invalidates sharers; in the sharers case both get Inv.
    let recalls = stats.counters.get("dir.invs_sent") + stats.counters.get("dir.fwds_sent");
    assert!(recalls >= 2, "both holders must lose the line: {recalls}");
}

#[test]
fn capacity_eviction_writes_back_dirty_lines() {
    // Write enough distinct lines to overflow L2 (256 KiB / 64 B = 4096
    // lines; way-conflict via matching set bits is faster: stride by
    // set-count × line so all map to one set).
    // L2: 256 KiB, 8 ways, 64 B lines → 512 sets; stride = 512 × 64.
    let stride = 512 * 64u64;
    let mut ops = Vec::new();
    for i in 0..16u64 {
        ops.push(st(0x10000 + i * stride));
        ops.extend(alu_n(2));
    }
    let stats = run(vec![Trace { ops }]);
    assert!(
        stats.counters.get("dir.putm") >= 8,
        "conflict misses must write back dirty victims: {}",
        stats.counters.get("dir.putm")
    );
    assert_eq!(stats.counters.get("cores_done"), 1);
}

#[test]
fn atomics_serialize_through_the_directory() {
    // All four cores AMO the same line; every AMO needs M, so ownership
    // ping-pongs: ≥ cores GetM transactions (first may be uncached).
    let mk = |pad: usize| {
        let mut ops = alu_n(pad);
        ops.push(amo(0x7000));
        ops.extend(alu_n(50));
        ops.push(amo(0x7000));
        Trace { ops }
    };
    let stats = run(vec![mk(0), mk(40), mk(80), mk(120)]);
    let getm = stats.counters.get("dir.getm");
    assert!(getm >= 4, "ownership must migrate between cores: {getm}");
    let recalls = stats.counters.get("dir.fwds_sent") + stats.counters.get("dir.invs_sent");
    assert!(recalls >= 3, "migration implies recalls: {recalls}");
}

#[test]
fn l1_inclusion_backinvalidate() {
    // Core 0 reads a line (in L1+L2); core 1 writes it. Core 0's L1 copy
    // must be back-invalidated (l1.invals counter).
    let mut ops0 = vec![ld(0x8000)];
    ops0.extend(alu_n(10));
    let mut ops1 = alu_n(400);
    ops1.push(st(0x8000));
    let stats = run(vec![Trace { ops: ops0 }, Trace { ops: ops1 }]);
    assert!(
        stats.counters.get("l1.invals") >= 1,
        "inclusion: L1 must drop the line the L2 lost"
    );
}

#[test]
fn coherence_traffic_rides_the_noc() {
    // Any recall crosses the mesh: flit counts must reflect the protocol
    // messages (requests, grants, recalls, acks).
    let t0 = Trace { ops: vec![ld(0x9000)] };
    let mut ops1 = alu_n(300);
    ops1.push(st(0x9000));
    let stats = run(vec![t0, Trace { ops: ops1 }]);
    // ≥ 6 one-way messages: GetS, DataE, GetM, FwdWbI, WbData, DataM.
    assert!(
        stats.counters.get("noc.flits_forwarded") >= 6,
        "protocol must traverse the NoC: {}",
        stats.counters.get("noc.flits_forwarded")
    );
}

#[test]
fn miss_latency_ordering_l2_vs_l3_vs_dram() {
    // Same-line second load (L1 hit) < L2 hit < DRAM miss, measured as
    // completion cycles of three single-op runs.
    let cold = run(vec![Trace { ops: vec![ld(0xA000)] }]).cycles;
    let l1 = run(vec![Trace {
        ops: vec![ld(0xA000), ld(0xA008)],
    }])
    .cycles;
    // Third case: two loads far apart → two cold misses.
    let two_cold = run(vec![Trace {
        ops: vec![ld(0xA000), ld(0xFF000)],
    }])
    .cycles;
    assert!(l1 < cold + 10, "L1 hit adds ~nothing: {l1} vs {cold}");
    assert!(
        two_cold > cold + 50,
        "second cold miss pays full latency: {two_cold} vs {cold}"
    );
}
