//! Shared fixtures for the integration suites: sleep-capable model
//! builders, the serial fingerprint reference runner, and the cartesian
//! serial-vs-ladder determinism matrix that `determinism.rs`,
//! `repartition.rs`, and `wakeup.rs` all drive.
//!
//! This module is compiled into each test binary via `mod common;`; the
//! binaries use different subsets of it, hence the file-level dead_code
//! allowance.
#![allow(dead_code)]

use scalesim::cpu::isa::{OpClass, TraceOp, NO_REG};
use scalesim::cpu::Trace;
use scalesim::engine::{
    Ctx, Engine, Fnv, In, Model, ModelBuilder, Msg, Out, Payload, PortCfg, RepartitionPolicy,
    RunOpts, SchedMode, Sim, Stop, Transit, Unit,
};
use scalesim::sched::PartitionStrategy;
use scalesim::stats::{RunStats, StatsMap};
use scalesim::sync::SyncMethod;
use scalesim::systems::{build_cpu_system, CpuSystemCfg};

// ---------------------------------------------------------------------
// Sleep-capable pipeline (the wake-protocol workout model)
// ---------------------------------------------------------------------

/// The pipeline's typed payload (sequence + accumulator), implementing
/// `Payload` outside the crate — the extension point the wiring layer
/// promises substrates.
#[derive(Debug, Clone, Copy)]
pub struct PM {
    pub seq: u64,
    pub acc: u64,
}

impl Payload for PM {
    fn encode(self) -> Msg {
        Msg::with(1, self.seq, self.acc, 0)
    }

    fn decode(m: &Msg) -> Self {
        PM { seq: m.a, acc: m.b }
    }
}

/// A pipeline stage that honours the sleep contract: the source is idle
/// once drained; mids and the sink are purely input-driven.
pub struct PipeStage {
    pub inp: Option<In<PM>>,
    pub out: Option<Out<PM>>,
    pub seq: u64,
    pub limit: u64,
    pub received: u64,
    pub acc: u64,
}

impl Unit for PipeStage {
    fn work(&mut self, ctx: &mut Ctx<'_>) {
        match (self.inp, self.out) {
            (None, Some(out)) => {
                if self.seq < self.limit && out.vacant(ctx) {
                    out.send(ctx, PM { seq: self.seq, acc: 0 }).unwrap();
                    self.seq += 1;
                }
            }
            (Some(inp), Some(out)) => {
                while out.vacant(ctx) {
                    let Some(mut m) = inp.recv(ctx) else { break };
                    m.acc = m.acc.wrapping_mul(31).wrapping_add(m.seq);
                    out.send(ctx, m).unwrap();
                }
            }
            (Some(inp), None) => {
                while let Some(m) = inp.recv(ctx) {
                    assert_eq!(m.seq, self.received, "FIFO broken");
                    self.received += 1;
                    self.acc = self.acc.wrapping_mul(31).wrapping_add(m.acc);
                }
            }
            (None, None) => {}
        }
    }

    fn state_hash(&self, h: &mut Fnv) {
        h.write_u64(self.seq);
        h.write_u64(self.received);
        h.write_u64(self.acc);
    }

    fn is_idle(&self) -> bool {
        self.seq >= self.limit
    }
}

/// Linear pipeline with mixed port delays (1,2,3,1,…) so in-flight
/// messages regularly outlive a receiver's last tick.
pub fn sleepy_pipeline(n: usize, msgs: u64) -> Model {
    let mut mb = ModelBuilder::new();
    let ids: Vec<u32> = (0..n).map(|i| mb.reserve_unit(&format!("p{i}"))).collect();
    let mut ports = Vec::new();
    for i in 0..n - 1 {
        let delay = 1 + (i as u64 % 3);
        ports.push(mb.link::<PM>(ids[i], ids[i + 1], PortCfg::new(2, delay)));
    }
    for i in 0..n {
        let unit = PipeStage {
            inp: if i == 0 { None } else { Some(ports[i - 1].1) },
            out: if i == n - 1 { None } else { Some(ports[i].0) },
            seq: 0,
            limit: if i == 0 { msgs } else { 0 },
            received: 0,
            acc: 0,
        };
        mb.install(ids[i], Box::new(unit));
    }
    mb.build().unwrap()
}

// ---------------------------------------------------------------------
// CPU system (cores + coherent memory + NoC) at test scale
// ---------------------------------------------------------------------

/// Deterministic little traces mixing loads, ALU ops, and (optionally)
/// stores — enough to light up the L1/L2/directory/NoC path.
pub fn cpu_traces(cores: u64, ops_per_core: u64, with_stores: bool) -> Vec<Trace> {
    (0..cores)
        .map(|c| Trace {
            ops: (0..ops_per_core)
                .map(|i| {
                    if i % 3 == 0 {
                        TraceOp::new(
                            OpClass::Load,
                            1,
                            2,
                            NO_REG,
                            0x1000 + ((c * 64 + i * 8) % 4096),
                            0,
                            false,
                        )
                    } else if with_stores && i % 7 == 0 {
                        TraceOp::new(OpClass::Store, NO_REG, 1, 2, 0x8000 + (i % 512), 0, false)
                    } else {
                        TraceOp::new(OpClass::Alu, 1, 1, 2, 0, 0, false)
                    }
                })
                .collect(),
        })
        .collect()
}

/// The light-core CPU system over [`cpu_traces`], with its all-cores-done
/// stop condition.
pub fn cpu_system(cores: u64, with_stores: bool) -> (Model, Stop) {
    let cfg = CpuSystemCfg::default();
    let (model, h) = build_cpu_system(cpu_traces(cores, 60, with_stores), &cfg);
    let stop = Stop::CounterAtLeast {
        counter: h.cores_done,
        target: cores,
        max_cycles: 100_000,
    };
    (model, stop)
}

// ---------------------------------------------------------------------
// Phase-flip cost model (the repartitioning stress workload)
// ---------------------------------------------------------------------

/// A unit whose work cost is a function of the cycle: heavy (a long
/// deterministic mix loop) on one side of `flip_at`, nearly free on the
/// other. State is a pure function of (id, cycles executed), so any
/// engine, partition, or migration schedule must produce the same
/// fingerprint — and a migration that ever skipped or repeated a tick
/// would be caught.
pub struct PhasedUnit {
    pub id: u64,
    pub heavy_before_flip: bool,
    pub flip_at: u64,
    pub acc: u64,
}

impl Unit for PhasedUnit {
    fn work(&mut self, ctx: &mut Ctx<'_>) {
        let heavy = (ctx.cycle < self.flip_at) == self.heavy_before_flip;
        if heavy {
            let mut x = self.acc ^ self.id ^ ctx.cycle;
            for _ in 0..2_000 {
                x = x.wrapping_mul(0x100000001B3).wrapping_add(1);
            }
            self.acc = self.acc.wrapping_add(x);
        } else {
            self.acc = self.acc.wrapping_add(ctx.cycle ^ self.id);
        }
    }

    fn state_hash(&self, h: &mut Fnv) {
        h.write_u64(self.acc);
    }

    fn always_active(&self) -> bool {
        true // cost model runs every cycle; never park
    }
}

/// 8 independent units: 0–3 heavy before the flip, 4–7 heavy after.
pub fn phased_model(flip_at: u64) -> Model {
    let mut mb = ModelBuilder::new();
    for i in 0..8u64 {
        mb.add_unit(
            &format!("ph{i}"),
            Box::new(PhasedUnit {
                id: i,
                heavy_before_flip: i < 4,
                flip_at,
                acc: 0,
            }),
        );
    }
    mb.build().unwrap()
}

/// The partition every phased-model stress starts from: all heavy units
/// on cluster 0 — massively imbalanced, so the first decision must see a
/// ~1000x skew (far beyond any timing noise).
pub fn phased_start_partition() -> Vec<Vec<u32>> {
    vec![vec![0, 1, 2, 3], vec![4, 5, 6, 7]]
}

// ---------------------------------------------------------------------
// Burst/relay/sink units (the lost-wakeup hazard workload)
// ---------------------------------------------------------------------

/// Sends one message at each scheduled cycle (retrying under back
/// pressure). Not idle until the whole schedule has been sent, so it
/// stays awake through the gaps — the *sink* is the unit that parks.
pub struct BurstSource {
    pub out: Out<Transit>,
    pub schedule: Vec<u64>,
    pub next: usize,
}

impl Unit for BurstSource {
    fn work(&mut self, ctx: &mut Ctx<'_>) {
        while let Some(&at) = self.schedule.get(self.next) {
            if at > ctx.cycle || !self.out.vacant(ctx) {
                break;
            }
            self.out
                .send_msg(ctx, Msg::with(1, self.next as u64, 0, 0))
                .unwrap();
            self.next += 1;
        }
    }

    fn state_hash(&self, h: &mut Fnv) {
        h.write_u64(self.next as u64);
    }

    fn is_idle(&self) -> bool {
        self.next >= self.schedule.len()
    }
}

/// Input-driven relay: forwards everything, parks whenever quiet.
pub struct Relay {
    pub inp: In<Transit>,
    pub out: Out<Transit>,
}

impl Unit for Relay {
    fn work(&mut self, ctx: &mut Ctx<'_>) {
        while self.out.vacant(ctx) {
            let Some(m) = self.inp.recv_msg(ctx) else { break };
            self.out.send_msg(ctx, m).unwrap();
        }
    }
}

/// Input-driven sink; `is_idle` defaults to `true`, so it parks whenever
/// its queue is empty — exactly the unit the lost-wakeup hazard targets.
pub struct CountingSink {
    pub inp: In<Transit>,
    pub received: u64,
}

impl Unit for CountingSink {
    fn work(&mut self, ctx: &mut Ctx<'_>) {
        while let Some(m) = self.inp.recv_msg(ctx) {
            assert_eq!(m.a, self.received, "FIFO order broken");
            self.received += 1;
        }
    }

    fn state_hash(&self, h: &mut Fnv) {
        h.write_u64(self.received);
    }

    fn stats(&self, out: &mut StatsMap) {
        out.add("sink.received", self.received);
    }
}

/// Source → sink over one port with the given delay; bursts separated by
/// gaps long enough for the sink to park in between.
pub fn burst_model(delay: u64) -> Model {
    let mut mb = ModelBuilder::new();
    let src = mb.reserve_unit("src");
    let snk = mb.reserve_unit("snk");
    let (tx, rx) = mb.link::<Transit>(src, snk, PortCfg::new(2, delay));
    mb.install(
        src,
        Box::new(BurstSource {
            out: tx,
            // Gaps of 10+ cycles: the sink drains, parks, and must be
            // re-awoken by a delivery whose delay is still running.
            schedule: vec![0, 1, 15, 16, 40, 70, 71, 72],
            next: 0,
        }),
    );
    mb.install(snk, Box::new(CountingSink { inp: rx, received: 0 }));
    mb.build().unwrap()
}

/// Three-hop chain so wakes must propagate: src → relay → sink.
pub fn chain_model(delay: u64) -> Model {
    let mut mb = ModelBuilder::new();
    let src = mb.reserve_unit("src");
    let mid = mb.reserve_unit("mid");
    let snk = mb.reserve_unit("snk");
    let (tx0, rx0) = mb.link::<Transit>(src, mid, PortCfg::new(2, delay));
    let (tx1, rx1) = mb.link::<Transit>(mid, snk, PortCfg::new(2, delay));
    mb.install(
        src,
        Box::new(BurstSource {
            out: tx0,
            schedule: vec![0, 20, 21, 50],
            next: 0,
        }),
    );
    mb.install(mid, Box::new(Relay { inp: rx0, out: tx1 }));
    mb.install(snk, Box::new(CountingSink { inp: rx1, received: 0 }));
    mb.build().unwrap()
}

pub fn all_idle() -> Stop {
    Stop::AllIdle {
        check_every: 1,
        max_cycles: 10_000,
    }
}

// ---------------------------------------------------------------------
// The fingerprint runner and the determinism matrix
// ---------------------------------------------------------------------

/// Run the serial reference engine over a fresh `(model, stop)` pair and
/// return its stats (fingerprint computed).
pub fn serial_reference(build: impl FnOnce() -> (Model, Stop)) -> RunStats {
    let (mut model, stop) = build();
    model.run_serial(RunOpts::with_stop(stop).fingerprinted())
}

/// One cartesian determinism sweep: which sync methods, worker counts,
/// partition strategies, scheduling modes, and repartition policies to
/// cross. Every dimension defaults to a single baseline cell — name only
/// the axes a test actually sweeps.
pub struct MatrixSpec<'a> {
    pub methods: &'a [SyncMethod],
    pub workers: &'a [usize],
    pub strategies: &'a [PartitionStrategy],
    pub scheds: &'a [SchedMode],
    pub repartition: &'a [RepartitionPolicy],
}

// Generic over the lifetime (not just 'static): the defaults are
// promoted constants, and callers mix them with borrows of locals via
// struct-update syntax.
impl Default for MatrixSpec<'_> {
    fn default() -> Self {
        MatrixSpec {
            methods: &[SyncMethod::CommonAtomic],
            workers: &[2],
            strategies: &[PartitionStrategy::Contiguous],
            scheds: &[SchedMode::FullScan],
            repartition: &[RepartitionPolicy::Off],
        }
    }
}

/// Run every cell of the matrix through the ladder engine on a fresh
/// model and assert its fingerprint and cycle count match the serial
/// reference — the paper's "result is agnostic to the order of
/// execution" claim, which every scheduling feature in this repo must
/// preserve.
pub fn assert_ladder_matrix(
    label: &str,
    reference: &RunStats,
    build: impl Fn() -> (Model, Stop),
    spec: MatrixSpec<'_>,
) {
    for &method in spec.methods {
        for &workers in spec.workers {
            for &strat in spec.strategies {
                for &sched in spec.scheds {
                    for &repart in spec.repartition {
                        let (model, stop) = build();
                        let stats = Sim::from_model(model)
                            .workers(workers)
                            .strategy(strat)
                            .sync(method)
                            .sched(sched)
                            .repartition(repart)
                            .stop(stop)
                            .fingerprinted()
                            .engine(Engine::Ladder)
                            .run()
                            .expect("ladder run")
                            .stats;
                        let cell = format!(
                            "{label}: method={} workers={workers} strat={} sched={} \
                             repart={}",
                            method.name(),
                            strat.name(),
                            sched.name(),
                            repart.summary(),
                        );
                        assert_eq!(stats.fingerprint, reference.fingerprint, "{cell}");
                        assert_eq!(stats.cycles, reference.cycles, "{cell}: cycles");
                    }
                }
            }
        }
    }
}
