//! Property tests for the paper's core correctness claims:
//!
//! 1. Parallel execution ≡ serial execution, for any model, worker count,
//!    partition strategy, and sync method ("the simulation result ... is
//!    indeed agnostic to the order of execution", §3.3/§5.1).
//! 2. Back pressure never drops or duplicates messages under random stall
//!    injection.
//! 3. Message causality: sent at m, consumable at n > m, under every
//!    delay/capacity configuration.
//!
//! No `proptest` in this offline environment, so properties are driven by
//! the deterministic Rng over many random cases (seeds printed on
//! failure).

mod common;

use common::{assert_ladder_matrix, cpu_system, serial_reference, sleepy_pipeline, MatrixSpec};
use scalesim::engine::{
    Ctx, Engine, Fnv, In, Model, ModelBuilder, Msg, Out, PortCfg, RepartitionPolicy, RunOpts,
    SchedMode, Sim, Stop, Transit, Unit,
};
use scalesim::sched::PartitionStrategy;
use scalesim::sync::SyncMethod;
use scalesim::util::config::Config;
use scalesim::util::rng::Rng;

/// A randomized unit: every cycle it may consume from each input, do some
/// state mixing, and may send on each output (if vacant). Behaviour is a
/// pure function of (unit seed, cycle, messages seen) — never of wall
/// clock or thread id — so any execution order must give the same result.
struct ChaosUnit {
    id: u64,
    rng: Rng,
    ins: Vec<In<Transit>>,
    outs: Vec<Out<Transit>>,
    state: u64,
    sent: u64,
    received: u64,
    /// Probability of *not* consuming an input this cycle (stall injection).
    stall_p: f64,
    send_p: f64,
}

impl Unit for ChaosUnit {
    fn work(&mut self, ctx: &mut Ctx<'_>) {
        for i in 0..self.ins.len() {
            if self.rng.gen_bool(self.stall_p) {
                continue; // injected stall: back pressure builds upstream
            }
            while let Some(m) = self.ins[i].recv_msg(ctx) {
                self.received += 1;
                self.state = self
                    .state
                    .wrapping_mul(0x100000001B3)
                    .wrapping_add(m.a ^ m.c);
            }
        }
        for o in 0..self.outs.len() {
            if self.rng.gen_bool(self.send_p) && self.outs[o].vacant(ctx) {
                let payload = self.state ^ (self.sent << 32) ^ self.id;
                self.outs[o]
                    .send_msg(ctx, Msg::with(1, payload, 0, self.sent))
                    .unwrap();
                self.sent += 1;
            }
        }
    }

    fn state_hash(&self, h: &mut Fnv) {
        h.write_u64(self.state);
        h.write_u64(self.sent);
        h.write_u64(self.received);
    }

    fn stats(&self, out: &mut scalesim::stats::StatsMap) {
        out.add("chaos.sent", self.sent);
        out.add("chaos.received", self.received);
    }

    fn always_active(&self) -> bool {
        // The rng advances on every call, so `work` is never a no-op:
        // sleeping would change behaviour. Opting out keeps ChaosUnit
        // usable under both scheduling modes.
        true
    }
}

/// Build a random connected model: `n` units, `e` random extra edges over
/// a ring backbone, random port configs.
fn random_model(seed: u64, n: usize, extra_edges: usize) -> Model {
    let mut rng = Rng::from_seed_stream(seed, 0x10DE1);
    let mut mb = ModelBuilder::new();
    let ids: Vec<u32> = (0..n).map(|i| mb.reserve_unit(&format!("u{i}"))).collect();
    let mut edges: Vec<(usize, usize)> = (0..n).map(|i| (i, (i + 1) % n)).collect();
    for _ in 0..extra_edges {
        let a = rng.gen_range(n as u64) as usize;
        let mut b = rng.gen_range(n as u64) as usize;
        if a == b {
            b = (b + 1) % n;
        }
        edges.push((a, b));
    }
    let mut unit_ins: Vec<Vec<In<Transit>>> = vec![Vec::new(); n];
    let mut unit_outs: Vec<Vec<Out<Transit>>> = vec![Vec::new(); n];
    for (a, b) in edges {
        let cfg = PortCfg {
            capacity: 1 + rng.gen_range(4) as usize,
            out_capacity: 1 + rng.gen_range(2) as usize,
            delay: 1 + rng.gen_range(3),
        };
        let (tx, rx) = mb.link::<Transit>(ids[a], ids[b], cfg);
        unit_outs[a].push(tx);
        unit_ins[b].push(rx);
    }
    for i in 0..n {
        let stall_p = rng.gen_f64() * 0.3;
        let send_p = 0.3 + rng.gen_f64() * 0.7;
        mb.install(
            ids[i],
            Box::new(ChaosUnit {
                id: i as u64,
                rng: Rng::from_seed_stream(seed, i as u64 + 100),
                ins: unit_ins[i].clone(),
                outs: unit_outs[i].clone(),
                state: 0,
                sent: 0,
                received: 0,
                stall_p,
                send_p,
            }),
        );
    }
    mb.build().unwrap()
}

#[test]
fn parallel_equals_serial_over_random_models() {
    for seed in 0..8u64 {
        let n = 4 + (seed as usize % 9);
        let cycles = 150;
        let serial = {
            let mut m = random_model(seed, n, 6);
            m.run_serial(RunOpts::cycles(cycles).fingerprinted())
        };
        for &method in &[SyncMethod::CommonAtomic, SyncMethod::Atomic] {
            for workers in [2, 3, 4] {
                for strat in [
                    PartitionStrategy::RoundRobin,
                    PartitionStrategy::Random(seed ^ 0x55),
                    PartitionStrategy::Locality,
                    PartitionStrategy::CostBalanced,
                    PartitionStrategy::CostLocality,
                ] {
                    let stats = Sim::from_model(random_model(seed, n, 6))
                        .workers(workers)
                        .strategy(strat)
                        .sync(method)
                        .cycles(cycles)
                        .fingerprinted()
                        .engine(Engine::Ladder)
                        .run()
                        .expect("ladder run")
                        .stats;
                    assert_eq!(
                        stats.fingerprint, serial.fingerprint,
                        "seed={seed} method={} workers={workers} strat={}",
                        method.name(),
                        strat.name()
                    );
                }
            }
        }
    }
}

#[test]
fn messages_conserved_under_stalls() {
    // Total sent == total received + in flight, for random stall patterns:
    // back pressure may delay but never drop or duplicate a message.
    for seed in 0..10u64 {
        let mut m = random_model(seed.wrapping_mul(77), 6, 4);
        let stats = m.run_serial(RunOpts::cycles(300));
        let sent = stats.counters.get("chaos.sent");
        let received = stats.counters.get("chaos.received");
        let in_flight = m.in_flight() as u64;
        assert_eq!(
            sent,
            received + in_flight,
            "seed={seed}: sent={sent} received={received} in_flight={in_flight}"
        );
        assert!(sent > 0, "seed={seed}: workload must generate traffic");
    }
}

/// A sender/receiver pair around a single port, verifying the causality
/// rule n > m for every (capacity, delay) combination.
struct SendEveryCycle {
    out: Out<Transit>,
}

impl Unit for SendEveryCycle {
    fn work(&mut self, ctx: &mut Ctx<'_>) {
        if self.out.vacant(ctx) {
            self.out
                .send_msg(ctx, Msg::with(1, ctx.cycle, 0, 0))
                .unwrap();
        }
    }
}

struct CheckCausality {
    inp: In<Transit>,
    delay: u64,
    checked: u64,
}

impl Unit for CheckCausality {
    fn work(&mut self, ctx: &mut Ctx<'_>) {
        while let Some(m) = self.inp.recv_msg(ctx) {
            let sent = m.a;
            assert!(
                ctx.cycle > sent,
                "consumed at {} but sent at {sent} (must be later)",
                ctx.cycle
            );
            assert!(
                ctx.cycle >= sent + self.delay,
                "delay {} not honoured: sent {sent}, got {}",
                self.delay,
                ctx.cycle
            );
            self.checked += 1;
        }
    }

    fn state_hash(&self, h: &mut Fnv) {
        h.write_u64(self.checked);
    }
}

#[test]
fn causality_holds_for_all_port_configs() {
    for capacity in [1usize, 2, 8] {
        for out_capacity in [1usize, 4] {
            for delay in [0u64, 1, 2, 5] {
                let mut mb = ModelBuilder::new();
                let a = mb.reserve_unit("send");
                let b = mb.reserve_unit("check");
                let (tx, rx) = mb.link::<Transit>(
                    a,
                    b,
                    PortCfg {
                        capacity,
                        out_capacity,
                        delay,
                    },
                );
                mb.install(a, Box::new(SendEveryCycle { out: tx }));
                mb.install(
                    b,
                    Box::new(CheckCausality {
                        inp: rx,
                        delay: delay.max(1),
                        checked: 0,
                    }),
                );
                let mut m = mb.build().unwrap();
                m.run_serial(RunOpts::cycles(100));
                // The checker's asserts fired inside the run if violated.
            }
        }
    }
}

// ---------------------------------------------------------------------
// Sleep-capable determinism matrix (ISSUE 1): fingerprints must agree
// across {serial full-scan, serial active-list, ladder × sync method ×
// worker count × partition strategy × sched mode} on models whose units
// genuinely park and re-arm. Model builders and the cartesian runner
// live in `tests/common`.
// ---------------------------------------------------------------------

#[test]
fn sleep_capable_pipeline_full_matrix() {
    let n = 8;
    let cycles = 400;
    let build = || (sleepy_pipeline(n, 60), Stop::Cycles(cycles));
    let reference = serial_reference(build);
    // Serial active-list against the full-scan reference.
    {
        let mut m = sleepy_pipeline(n, 60);
        let s = m.run_serial(RunOpts::cycles(cycles).fingerprinted().active_list());
        assert_eq!(s.fingerprint, reference.fingerprint, "serial active-list");
        assert!(
            s.unit_ticks() < reference.unit_ticks(),
            "pipeline must actually park: {} vs {}",
            s.unit_ticks(),
            reference.unit_ticks()
        );
    }
    // Every ladder combination, both scheduling modes.
    assert_ladder_matrix(
        "pipeline",
        &reference,
        build,
        MatrixSpec {
            methods: &SyncMethod::ALL,
            workers: &[1, 2, 4],
            strategies: &[
                PartitionStrategy::RoundRobin,
                PartitionStrategy::Random(0x55),
                PartitionStrategy::Locality,
                PartitionStrategy::Contiguous,
                PartitionStrategy::CostBalanced,
                PartitionStrategy::CostLocality,
            ],
            scheds: &[SchedMode::FullScan, SchedMode::ActiveList],
            ..Default::default()
        },
    );
}

#[test]
fn sleep_capable_cpu_system_matrix() {
    let build = || cpu_system(4, true);
    let reference = serial_reference(build);
    assert_eq!(reference.counters.get("cores_done"), 4);

    // Serial active-list.
    {
        let (mut m, stop) = build();
        let s = m.run_serial(RunOpts::with_stop(stop).fingerprinted().active_list());
        assert_eq!(s.fingerprint, reference.fingerprint, "serial active-list");
        assert_eq!(s.cycles, reference.cycles);
    }
    // Ladder sweep (reduced matrix: the pipeline test covers all four
    // methods; here the heavier model covers both atomics end-to-end).
    assert_ladder_matrix(
        "cpu-system",
        &reference,
        build,
        MatrixSpec {
            methods: &[SyncMethod::CommonAtomic, SyncMethod::Atomic],
            workers: &[2, 3],
            strategies: &[
                PartitionStrategy::Contiguous,
                PartitionStrategy::CostLocality,
            ],
            scheds: &[SchedMode::FullScan, SchedMode::ActiveList],
            ..Default::default()
        },
    );
}

// ---------------------------------------------------------------------
// Repartitioning determinism matrix (ISSUE 3 + ISSUE 5): migration is a
// barrier-side data-structure swap, so fingerprints must be bit-identical
// across {off, fixed N=16/256, drift-adaptive} × worker counts × both
// scheduling modes — regardless of when (or whether) the timing-driven
// decisions fire on a given host. The adaptive rows use a zero drift
// threshold (plan at every probe) and a zero-hysteresis gate: the most
// migration-happy configuration is the strongest check.
// ---------------------------------------------------------------------

/// The repartition axis shared by both invisibility matrices.
fn migration_happy_policies() -> [RepartitionPolicy; 4] {
    [
        RepartitionPolicy::Off,
        RepartitionPolicy::Fixed {
            interval_cycles: 16,
            hysteresis: 0.0,
            max_moves: usize::MAX,
        },
        RepartitionPolicy::Fixed {
            interval_cycles: 256,
            hysteresis: 0.0,
            max_moves: usize::MAX,
        },
        RepartitionPolicy::Adaptive {
            check_every: 16,
            drift_threshold: 0.0,
            backoff: 2,
            hysteresis: 0.0,
            max_moves: usize::MAX,
        },
    ]
}

#[test]
fn repartitioning_is_invisible_on_the_pipeline_matrix() {
    let n = 8;
    let cycles = 400;
    let build = || (sleepy_pipeline(n, 60), Stop::Cycles(cycles));
    let reference = serial_reference(build);
    assert_ladder_matrix(
        "pipeline+repart",
        &reference,
        build,
        MatrixSpec {
            workers: &[1, 2, 4],
            scheds: &[SchedMode::FullScan, SchedMode::ActiveList],
            repartition: &migration_happy_policies(),
            ..Default::default()
        },
    );
    // Nothing to migrate with one cluster: the policy must be a no-op.
    let stats = Sim::from_model(sleepy_pipeline(n, 60))
        .workers(1)
        .repartition(RepartitionPolicy::every(16))
        .cycles(cycles)
        .fingerprinted()
        .engine(Engine::Ladder)
        .run()
        .expect("ladder run")
        .stats;
    assert_eq!(stats.repart.events, 0, "one cluster: nothing to migrate");
}

#[test]
fn repartitioning_is_invisible_on_the_cpu_system() {
    let build = || cpu_system(4, false);
    let reference = serial_reference(build);
    assert_ladder_matrix(
        "cpu-system+repart",
        &reference,
        build,
        MatrixSpec {
            workers: &[2, 4],
            scheds: &[SchedMode::FullScan, SchedMode::ActiveList],
            repartition: &migration_happy_policies(),
            ..Default::default()
        },
    );
}

#[test]
fn sync_ops_scale_with_workers_not_model_size() {
    let count_ops = |units: usize, workers: usize| {
        Sim::from_model(random_model(3, units, 4))
            .workers(workers)
            .strategy(PartitionStrategy::RoundRobin)
            .sync(SyncMethod::CommonAtomic)
            .cycles(100)
            .engine(Engine::Ladder)
            .run()
            .expect("ladder run")
            .stats
            .sync_ops
    };
    let small = count_ops(6, 2);
    let large = count_ops(24, 2);
    assert_eq!(small, large, "model size must not affect sync ops");
    let more_workers = count_ops(24, 4);
    assert!(more_workers > large, "workers do affect sync ops");
}

// ---------------------------------------------------------------------
// Typed-wiring scenario matrix (ISSUE 4 + ISSUE 5): the combinator-built
// ring, torus, and tree NoCs must run deterministically across workers
// {1,2,4}, both scheduling modes, and the cost-locality strategy (whose
// planner is now the KL refinement) — fingerprints equal to their serial
// reference in every cell.
// ---------------------------------------------------------------------

#[test]
fn ring_torus_and_tree_scenarios_full_matrix() {
    let configs: Vec<(&str, Config)> = vec![
        ("ring", {
            let mut c = Config::new();
            c.set("nodes", 8);
            c.set("packets", 12);
            c
        }),
        ("torus", {
            let mut c = Config::new();
            c.set("dim", 3);
            c.set("packets", 8);
            c
        }),
        ("tree", {
            let mut c = Config::new();
            c.set("fanout", 3);
            c.set("depth", 3);
            c.set("packets", 6);
            c
        }),
        // Credit-looped bursty variant: gated injection + credit returns
        // riding the data network must stay order-agnostic too.
        ("ring", {
            let mut c = Config::new();
            c.set("nodes", 6);
            c.set("packets", 8);
            c.set("credits", 1);
            c.set("burst", "6:6");
            c
        }),
        // Fan-in storm through the flow kit (generators → credit loops →
        // round-robin arbiter): the stall/grant counters ride the
        // fingerprinted state, so every cell must agree bit-for-bit.
        ("incast", {
            let mut c = Config::new();
            c.set("hosts", 6);
            c.set("packets", 8);
            c.set("credits", 2);
            c.set("burst", "4:8");
            c
        }),
    ];
    for (name, cfg) in &configs {
        let build = || scalesim::scenario::find(name).unwrap().build(cfg).unwrap();
        let reference = serial_reference(build);
        assert!(
            reference.cycles < 500_000,
            "{name}: serial run must drain, not hit the cap"
        );
        assert_ladder_matrix(
            name,
            &reference,
            build,
            MatrixSpec {
                workers: &[1, 2, 4],
                scheds: &[SchedMode::FullScan, SchedMode::ActiveList],
                strategies: &[
                    PartitionStrategy::Contiguous,
                    PartitionStrategy::CostBalanced,
                    PartitionStrategy::CostLocality,
                ],
                ..Default::default()
            },
        );
    }
}

#[test]
fn cost_locality_cuts_fewer_ports_than_cost_balanced_on_torus() {
    use scalesim::engine::Sim;
    let mut cfg = Config::new();
    cfg.set("dim", 4);
    cfg.set("packets", 8);
    // A fixed skewed-but-comparable cost vector: deterministic on every
    // host (wall-clock profiling would make this test flaky), and
    // effectively arbitrary with respect to the topology — exactly the
    // regime where edge-blind LPT shreds the torus.
    let units = Sim::scenario("torus", &cfg).unwrap().model().num_units();
    let costs: Vec<u64> = (0..units as u64).map(|i| 100 + (i * 7919) % 97).collect();
    let run = |strat: PartitionStrategy| {
        Sim::scenario("torus", &cfg)
            .unwrap()
            .workers(4)
            .strategy(strat)
            .unit_costs(costs.clone())
            .fingerprinted()
            .engine(Engine::Ladder)
            .run()
            .unwrap()
    };
    let balanced = run(PartitionStrategy::CostBalanced);
    let locality = run(PartitionStrategy::CostLocality);
    assert_eq!(
        balanced.fingerprint(),
        locality.fingerprint(),
        "partitioning is a performance knob, never a semantic one"
    );
    assert!(
        locality.stats.cross_cluster_ports < balanced.stats.cross_cluster_ports,
        "cost-locality must cut strictly fewer ports: {} vs {}",
        locality.stats.cross_cluster_ports,
        balanced.stats.cross_cluster_ports
    );
    assert!(locality.to_json().contains("\"cross_cluster_ports\""));
}
