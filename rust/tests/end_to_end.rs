//! End-to-end integration: full workloads through full systems.
//!
//! These are the heavyweight composition tests: OLTP traces through the
//! light and OOO multicore systems (FM → PM → coherent memory → NoC), and
//! the paper's headline determinism claim on those systems.

use scalesim::cpu::ooo::OooCfg;
use scalesim::engine::{Engine, RunOpts, Sim, Stop};
use scalesim::sched::PartitionStrategy;
use scalesim::sync::SyncMethod;
use scalesim::systems::{build_cpu_system, CoreKind, CpuSystemCfg};
use scalesim::workload::{generate_oltp_traces, generate_spec_traces, OltpCfg, SpecKind};

fn oltp_cfg(cores: usize) -> OltpCfg {
    OltpCfg {
        cores,
        rows: 256,
        theta: 0.6,
        txns_per_core: 12,
        write_frac: 0.5,
        index_depth: 2,
        row_words: 2,
        max_instrs_per_core: 50_000,
        seed: 0xE2E,
    }
}

fn run_system(kind: CoreKind, cores: usize) -> scalesim::stats::RunStats {
    let traces = generate_oltp_traces(&oltp_cfg(cores));
    let cfg = CpuSystemCfg {
        kind,
        ..Default::default()
    };
    let (mut model, h) = build_cpu_system(traces, &cfg);
    model.run_serial(RunOpts::with_stop(Stop::CounterAtLeast {
        counter: h.cores_done,
        target: cores as u64,
        max_cycles: 2_000_000,
    }))
}

#[test]
fn oltp_on_light_cores_completes_with_coherence_traffic() {
    let stats = run_system(CoreKind::Light, 4);
    assert_eq!(stats.counters.get("cores_done"), 4, "{}", stats.summary());
    // The in-order core retires every trace op exactly once.
    let expected: u64 = generate_oltp_traces(&oltp_cfg(4))
        .iter()
        .map(|t| t.len() as u64)
        .sum();
    assert_eq!(stats.counters.get("core.retired"), expected);
    assert!(expected > 500, "workload non-trivial: {expected}");
    // OLTP on shared rows must exercise the full protocol.
    assert!(stats.counters.get("dir.gets") > 0, "read misses");
    assert!(stats.counters.get("dir.getm") > 0, "write upgrades");
    assert!(
        stats.counters.get("dir.invs_sent") + stats.counters.get("dir.fwds_sent") > 0,
        "shared hot rows must cause coherence recalls"
    );
    assert!(stats.counters.get("dram.reads") > 0);
}

#[test]
fn oltp_on_ooo_cores_is_faster_than_light() {
    let light = run_system(CoreKind::Light, 2);
    let ooo = run_system(CoreKind::Ooo(OooCfg::default()), 2);
    assert_eq!(ooo.counters.get("cores_done"), 2, "{}", ooo.summary());
    let light_ipc =
        light.counters.get("core.retired") as f64 / light.cycles.max(1) as f64;
    let ooo_ipc = ooo.counters.get("core.retired") as f64 / ooo.cycles.max(1) as f64;
    assert!(
        ooo_ipc > light_ipc,
        "OOO must beat in-order IPC: {ooo_ipc:.3} vs {light_ipc:.3}"
    );
    assert!(ooo.counters.get("ooo.bpred_predictions") > 0);
}

#[test]
fn ooo_system_parallel_matches_serial() {
    let mk = || {
        let traces = generate_oltp_traces(&oltp_cfg(4));
        build_cpu_system(
            traces,
            &CpuSystemCfg {
                kind: CoreKind::Ooo(OooCfg::default()),
                ..Default::default()
            },
        )
    };
    let (mut serial, h) = mk();
    let stop = Stop::CounterAtLeast {
        counter: h.cores_done,
        target: 4,
        max_cycles: 2_000_000,
    };
    let s = serial.run_serial(RunOpts::with_stop(stop).fingerprinted());
    let (par, h2) = mk();
    let stop2 = Stop::CounterAtLeast {
        counter: h2.cores_done,
        target: 4,
        max_cycles: 2_000_000,
    };
    let p = Sim::from_model(par)
        .workers(3)
        .strategy(PartitionStrategy::Contiguous)
        .sync(SyncMethod::CommonAtomic)
        .stop(stop2)
        .fingerprinted()
        .engine(Engine::Ladder)
        .run()
        .expect("ladder run")
        .stats;
    assert_eq!(p.fingerprint, s.fingerprint);
    assert_eq!(p.cycles, s.cycles);
    assert_eq!(
        p.counters.get("core.retired"),
        s.counters.get("core.retired")
    );
}

#[test]
fn spec_kernels_show_expected_performance_ordering() {
    // Compute-bound kernel should have much higher IPC than pointer-chase
    // on the OOO core.
    let run_kernel = |kind: SpecKind| {
        let traces = generate_spec_traces(kind, 1, 800, 200_000, 11);
        let (mut model, h) = build_cpu_system(
            traces,
            &CpuSystemCfg {
                kind: CoreKind::Ooo(OooCfg::default()),
                ..Default::default()
            },
        );
        let stats = model.run_serial(RunOpts::with_stop(Stop::CounterAtLeast {
            counter: h.cores_done,
            target: 1,
            max_cycles: 5_000_000,
        }));
        assert_eq!(stats.counters.get("cores_done"), 1, "{kind:?}");
        stats.counters.get("core.retired") as f64 / stats.cycles.max(1) as f64
    };
    let compute_ipc = run_kernel(SpecKind::Compute);
    let chase_ipc = run_kernel(SpecKind::PointerChase);
    assert!(
        compute_ipc > 2.0 * chase_ipc,
        "ILP kernel must far outrun pointer chase: {compute_ipc:.3} vs {chase_ipc:.3}"
    );
}
