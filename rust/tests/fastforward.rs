//! Idle-cycle fast-forward (DESIGN.md §2f), end to end:
//!
//! 1. Bit-identity: `--ff on` and `--ff off` must produce the same
//!    fingerprint and the same final cycle count on every scenario ×
//!    engine × scheduling × repartition cell — the skip elides empty
//!    cycles, it never renumbers them.
//! 2. Checkpoints land on schedule even when their boundary falls inside
//!    a skipped region, and a restore from such a snapshot finishes
//!    bit-identical to an uninterrupted run.
//! 3. Effectiveness: on a sparse workload (a tree fabric that drains
//!    early under a long fixed-cycle stop) the skip must actually elide
//!    most of the clock, in both the serial and ladder engines.
//!
//! The active-list cells here also regression-test the stall watchdog's
//! jump debounce: a fast-forward jump produces a zero-tick epoch by
//! design, and a false "lost wakeup" would fail these runs.

use scalesim::engine::{Engine, SchedMode, Sim};
use scalesim::util::config::Config;

fn cfg(pairs: &[(&str, &str)]) -> Config {
    let mut c = Config::new();
    for (k, v) in pairs {
        c.set(k, v);
    }
    c
}

/// Apply one engine-topology cell to a session.
fn topo(sim: Sim, workers: usize, sched: SchedMode) -> Sim {
    let engine = if workers <= 1 {
        Engine::Serial
    } else {
        Engine::Ladder
    };
    sim.workers(workers).engine(engine).sched(sched).fingerprinted()
}

/// Every (workers, sched, ff) cell of one scenario config must match the
/// ff-off serial reference in fingerprint and final cycle count.
fn assert_ff_parity_matrix(scenario: &str, pairs: &[(&str, &str)]) {
    let c = cfg(pairs);
    let reference = topo(Sim::scenario(scenario, &c).unwrap(), 1, SchedMode::FullScan)
        .ff(false)
        .run()
        .unwrap_or_else(|e| panic!("{scenario}: reference run: {e}"));
    assert_ne!(reference.fingerprint(), 0, "{scenario}: no fingerprint");

    for workers in [1usize, 2, 4] {
        for sched in [SchedMode::FullScan, SchedMode::ActiveList] {
            for ff in [true, false] {
                let r = topo(Sim::scenario(scenario, &c).unwrap(), workers, sched)
                    .ff(ff)
                    .run()
                    .unwrap_or_else(|e| {
                        panic!("{scenario} workers={workers} ff={ff}: {e}")
                    });
                let cell = format!(
                    "{scenario}: workers={workers} sched={} ff={ff}",
                    sched.name()
                );
                assert_eq!(r.fingerprint(), reference.fingerprint(), "{cell}");
                assert_eq!(r.stats.cycles, reference.stats.cycles, "{cell}: cycles");
                if !ff {
                    assert_eq!(r.stats.skipped_cycles, 0, "{cell}: off must not skip");
                    assert_eq!(r.stats.ff_jumps, 0, "{cell}: off must not jump");
                }
            }
        }
    }
}

#[test]
fn pipeline_parity() {
    assert_ff_parity_matrix(
        "pipeline",
        &[("stages", "6"), ("messages", "40"), ("cycles", "300")],
    );
}

#[test]
fn cpu_light_parity() {
    assert_ff_parity_matrix(
        "cpu-light",
        &[("cores", "4"), ("txns", "20"), ("rows", "128"), ("cycles", "400")],
    );
}

#[test]
fn ring_parity() {
    assert_ff_parity_matrix(
        "ring",
        &[("nodes", "8"), ("packets", "8"), ("cycles", "400")],
    );
}

#[test]
fn torus_parity() {
    assert_ff_parity_matrix("torus", &[("dim", "3"), ("packets", "8"), ("cycles", "300")]);
}

#[test]
fn tree_parity() {
    // Sparse: 21 nodes × 2 packets drain long before cycle 600, so the
    // ff-on cells really do jump (the effectiveness test asserts it).
    assert_ff_parity_matrix(
        "tree",
        &[("fanout", "4"), ("depth", "3"), ("packets", "2"), ("cycles", "600")],
    );
}

#[test]
fn parity_holds_under_repartitioning() {
    // Fixed and adaptive repartitioning clamp the jump at their next
    // cadence point, so probes still fire on schedule; the execution
    // must stay bit-identical either way.
    for scenario_pairs in [
        ("pipeline", vec![("stages", "6"), ("messages", "40"), ("cycles", "300")]),
        ("tree", vec![("fanout", "4"), ("depth", "3"), ("packets", "2"), ("cycles", "600")]),
    ] {
        let (scenario, base) = scenario_pairs;
        let c = cfg(&base);
        let reference = topo(Sim::scenario(scenario, &c).unwrap(), 1, SchedMode::FullScan)
            .ff(false)
            .run()
            .unwrap();
        for repart in ["50", "adaptive"] {
            let mut pairs = base.clone();
            pairs.push(("repartition", repart));
            let c = cfg(&pairs);
            for ff in [true, false] {
                let r = topo(Sim::scenario(scenario, &c).unwrap(), 2, SchedMode::ActiveList)
                    .ff(ff)
                    .run()
                    .unwrap_or_else(|e| panic!("{scenario} repart={repart} ff={ff}: {e}"));
                assert_eq!(
                    r.fingerprint(),
                    reference.fingerprint(),
                    "{scenario}: repart={repart} ff={ff}"
                );
                assert_eq!(r.stats.cycles, reference.stats.cycles);
            }
        }
    }
}

#[test]
fn checkpoint_inside_a_skipped_region_restores_bit_identical() {
    // The tree drains within ~100 cycles; the cycle-200 and cycle-400
    // snapshot boundaries both fall in the idle tail, so the jump must
    // clamp at them, write the snapshot, and keep going.
    let pairs = [
        ("fanout", "4"),
        ("depth", "3"),
        ("packets", "2"),
        ("cycles", "600"),
    ];
    let c = cfg(&pairs);
    let full = topo(Sim::scenario("tree", &c).unwrap(), 2, SchedMode::ActiveList)
        .run()
        .unwrap();
    assert!(full.stats.skipped_cycles > 0, "the tail must be skipped");

    let path = std::env::temp_dir()
        .join(format!("scalesim_ff_ckpt_{}.snap", std::process::id()));
    let interrupted = topo(Sim::scenario("tree", &c).unwrap(), 2, SchedMode::ActiveList)
        .cycles(400)
        .checkpoint_every(200, &path)
        .run()
        .unwrap();
    assert_eq!(interrupted.stats.cycles, 400, "truncated stop");
    assert!(
        interrupted.stats.skipped_cycles > 0,
        "the snapshot boundaries sit inside skipped regions: {:?}",
        interrupted.stats.skipped_cycles
    );
    assert!(path.exists(), "no snapshot written");

    let restored = topo(Sim::restore(&path).unwrap(), 2, SchedMode::ActiveList)
        .run()
        .unwrap();
    let _ = std::fs::remove_file(&path);
    assert_eq!(restored.fingerprint(), full.fingerprint());
    assert_eq!(restored.stats.cycles, full.stats.cycles);
}

#[test]
fn sparse_tree_skips_most_of_the_clock() {
    let pairs = [
        ("fanout", "4"),
        ("depth", "3"),
        ("packets", "2"),
        ("cycles", "2000"),
    ];
    let c = cfg(&pairs);
    for (workers, sched) in [
        (1, SchedMode::FullScan),
        (1, SchedMode::ActiveList),
        (2, SchedMode::ActiveList),
    ] {
        let r = topo(Sim::scenario("tree", &c).unwrap(), workers, sched)
            .run()
            .unwrap_or_else(|e| panic!("workers={workers}: {e}"));
        let cell = format!("workers={workers} sched={}", sched.name());
        assert_eq!(r.stats.cycles, 2000, "{cell}: the clock still reaches the stop");
        assert!(r.stats.ff_jumps >= 1, "{cell}: no jump taken");
        assert!(
            r.stats.skipped_cycles > 1000,
            "{cell}: the ~1900-cycle idle tail must be elided, \
             skipped only {}",
            r.stats.skipped_cycles
        );
        // The work actually performed is bounded by the busy prefix, not
        // the simulated span: ticks ≪ cycles × units.
        let ceiling = 2000 * r.units as u64;
        assert!(
            r.stats.unit_ticks() < ceiling / 4,
            "{cell}: {} ticks is not sparse against {ceiling}",
            r.stats.unit_ticks()
        );
    }

    // And with the knob off, nothing is skipped — the measurement
    // baseline the speedup claim divides by.
    let off = topo(Sim::scenario("tree", &c).unwrap(), 1, SchedMode::FullScan)
        .ff(false)
        .run()
        .unwrap();
    assert_eq!(off.stats.skipped_cycles, 0);
    assert_eq!(off.stats.ff_jumps, 0);
    assert_eq!(off.stats.cycles, 2000);
}
