//! Flow-control & arbitration kit, end to end (ISSUE 9):
//!
//! 1. The incast fan-in storm is bit-identical across serial and ladder
//!    execution at every worker count — congestion (credit stalls, arbiter
//!    grants) is part of the deterministic result, not a timing artifact.
//! 2. Credit conservation: no credit is leaked or duplicated across a
//!    checkpoint → kill → restore cycle; after full drain every limiter
//!    holds exactly its provisioned pool again.
//! 3. Provisioning legibility: under-provisioned credit loops stall
//!    (nonzero `flow.credits_stalled`), over-provisioned ones never do.
//! 4. Fast-forward parity: the delay-line/burst `next_event` hints elide
//!    idle cycles without renumbering them — `--ff on` and `--ff off`
//!    agree on fingerprint and final cycle.

use scalesim::engine::{Engine, SchedMode, Sim};
use scalesim::util::config::Config;

fn cfg(pairs: &[(&str, &str)]) -> Config {
    let mut c = Config::new();
    for (k, v) in pairs {
        c.set(k, v);
    }
    c
}

/// Apply one engine-topology cell to a session.
fn topo(sim: Sim, workers: usize, sched: SchedMode) -> Sim {
    let engine = if workers <= 1 {
        Engine::Serial
    } else {
        Engine::Ladder
    };
    sim.workers(workers).engine(engine).sched(sched).fingerprinted()
}

/// hosts=8 × packets=12 with a 2-deep credit loop behind a rate-1 arbiter:
/// eight sources into one sink is 8× over-subscribed, so the loops *must*
/// run dry while the storm is live.
const UNDER_PROVISIONED: &[(&str, &str)] = &[
    ("hosts", "8"),
    ("packets", "12"),
    ("credits", "2"),
    ("burst", "6:10"),
];

#[test]
fn incast_is_bit_identical_across_worker_counts() {
    let c = cfg(UNDER_PROVISIONED);
    let reference = topo(Sim::scenario("incast", &c).unwrap(), 1, SchedMode::FullScan)
        .run()
        .unwrap();
    assert_ne!(reference.fingerprint(), 0, "no fingerprint");
    assert_eq!(
        reference.stats.counters.get("flow.delivered"),
        8 * 12,
        "every packet must land"
    );
    assert_eq!(
        reference.stats.counters.get("flow.arb_grants"),
        8 * 12,
        "each packet crosses the switch exactly once"
    );
    assert!(
        reference.stats.counters.get("flow.credits_stalled") > 0,
        "an 8×-over-subscribed switch must starve the credit loops"
    );

    for workers in [1usize, 2, 4] {
        for sched in [SchedMode::FullScan, SchedMode::ActiveList] {
            let r = topo(Sim::scenario("incast", &c).unwrap(), workers, sched)
                .run()
                .unwrap_or_else(|e| panic!("workers={workers}: {e}"));
            let cell = format!("workers={workers} sched={}", sched.name());
            assert_eq!(r.fingerprint(), reference.fingerprint(), "{cell}");
            assert_eq!(r.stats.cycles, reference.stats.cycles, "{cell}: cycles");
            assert_eq!(
                r.stats.counters.get("flow.credits_stalled"),
                reference.stats.counters.get("flow.credits_stalled"),
                "{cell}: stall accounting must be execution-order-agnostic"
            );
        }
    }
}

#[test]
fn over_provisioned_incast_never_stalls() {
    // 64 credits per host against 12 packets: the loop can never run dry,
    // whatever the arbiter does.
    let c = cfg(&[
        ("hosts", "8"),
        ("packets", "12"),
        ("credits", "64"),
        ("burst", "6:10"),
    ]);
    let r = topo(Sim::scenario("incast", &c).unwrap(), 2, SchedMode::ActiveList)
        .run()
        .unwrap();
    assert_eq!(r.stats.counters.get("flow.delivered"), 8 * 12);
    assert_eq!(
        r.stats.counters.get("flow.credits_stalled"),
        0,
        "an over-provisioned loop must never report a stall"
    );
}

#[test]
fn credits_are_conserved_across_checkpoint_kill_restore() {
    // A fixed-cycle stop comfortably past drain: after the storm, every
    // credit must be back home — `flow.credits` (the summed live pools)
    // equals hosts × credits again, on the uninterrupted run *and* on a
    // run that was checkpointed, killed, and restored mid-storm.
    let pairs = [
        ("hosts", "4"),
        ("packets", "8"),
        ("credits", "2"),
        ("burst", "4:4"),
        ("cycles", "4000"),
    ];
    let c = cfg(&pairs);
    let full = topo(Sim::scenario("incast", &c).unwrap(), 2, SchedMode::ActiveList)
        .run()
        .unwrap();
    assert_eq!(full.stats.counters.get("flow.delivered"), 4 * 8);
    assert_eq!(
        full.stats.counters.get("flow.credits"),
        4 * 2,
        "after drain every limiter must hold its full pool again"
    );

    let path = std::env::temp_dir()
        .join(format!("scalesim_flow_ckpt_{}.snap", std::process::id()));
    // Kill at cycle 60: mid-storm, with credits split between limiter
    // pools, issuer pending counts, and in-flight credit messages.
    let interrupted = topo(Sim::scenario("incast", &c).unwrap(), 2, SchedMode::ActiveList)
        .cycles(60)
        .checkpoint_every(30, &path)
        .run()
        .unwrap();
    assert_eq!(interrupted.stats.cycles, 60, "truncated stop");
    assert!(path.exists(), "no snapshot written");

    let restored = topo(Sim::restore(&path).unwrap(), 2, SchedMode::ActiveList)
        .run()
        .unwrap();
    let _ = std::fs::remove_file(&path);
    assert_eq!(
        restored.fingerprint(),
        full.fingerprint(),
        "restored run diverged from the uninterrupted run"
    );
    assert_eq!(restored.stats.cycles, full.stats.cycles);
    assert_eq!(
        restored.stats.counters.get("flow.credits"),
        4 * 2,
        "a credit leaked or duplicated across the snapshot boundary"
    );
    assert_eq!(
        restored.stats.counters.get("flow.delivered"),
        4 * 8,
        "delivery count diverged across the snapshot boundary"
    );
}

#[test]
fn fast_forward_parity_and_effectiveness_on_incast() {
    // burst=4:28 leaves long per-host off-windows; the generators hint
    // their next active edge and the delay lines hint their head release,
    // so the engines can jump the silence — without changing the result.
    let c = cfg(&[
        ("hosts", "4"),
        ("packets", "6"),
        ("credits", "4"),
        ("burst", "4:28"),
    ]);
    let on = topo(Sim::scenario("incast", &c).unwrap(), 1, SchedMode::ActiveList)
        .run()
        .unwrap();
    assert!(
        on.stats.skipped_cycles > 0,
        "the off-windows must actually fast-forward"
    );

    for workers in [1usize, 2] {
        let off = topo(Sim::scenario("incast", &c).unwrap(), workers, SchedMode::ActiveList)
            .ff(false)
            .run()
            .unwrap();
        assert_eq!(off.stats.skipped_cycles, 0, "ff off must not skip");
        assert_eq!(off.stats.ff_jumps, 0, "ff off must not jump");
        assert_eq!(
            off.fingerprint(),
            on.fingerprint(),
            "workers={workers}: ff must elide cycles, never renumber them"
        );
        assert_eq!(off.stats.cycles, on.stats.cycles, "workers={workers}");
    }
}

#[test]
fn congestion_counters_ride_the_json_report() {
    let c = cfg(UNDER_PROVISIONED);
    let r = topo(Sim::scenario("incast", &c).unwrap(), 2, SchedMode::ActiveList)
        .run()
        .unwrap();
    let json = r.to_json();
    assert!(
        json.contains("\"credits_stalled\""),
        "RunReport::to_json must carry the stall counter: {json}"
    );
    assert!(json.contains("\"arb_grants\""), "{json}");
}

#[test]
fn credit_looped_bursty_topologies_match_their_serial_reference() {
    // The retrofitted ring/torus/tree families: gated injection with
    // credit returns riding the data network, staggered burst envelopes.
    let configs: &[(&str, &[(&str, &str)])] = &[
        (
            "ring",
            &[
                ("nodes", "6"),
                ("packets", "8"),
                ("credits", "1"),
                ("burst", "6:2"),
            ],
        ),
        (
            "torus",
            &[
                ("dim", "3"),
                ("packets", "6"),
                ("credits", "2"),
                ("burst", "4:4"),
            ],
        ),
        (
            "tree",
            &[
                ("fanout", "2"),
                ("depth", "3"),
                ("packets", "8"),
                ("credits", "2"),
                ("burst", "4:4"),
            ],
        ),
    ];
    for (name, pairs) in configs {
        let c = cfg(pairs);
        let reference = topo(Sim::scenario(name, &c).unwrap(), 1, SchedMode::FullScan)
            .run()
            .unwrap_or_else(|e| panic!("{name}: serial: {e}"));
        assert!(
            reference.stats.counters.get(&format!("{name}.delivered")) > 0,
            "{name}: nothing delivered"
        );
        for workers in [2usize, 4] {
            let r = topo(Sim::scenario(name, &c).unwrap(), workers, SchedMode::ActiveList)
                .run()
                .unwrap_or_else(|e| panic!("{name} workers={workers}: {e}"));
            assert_eq!(
                r.fingerprint(),
                reference.fingerprint(),
                "{name}: workers={workers}"
            );
            assert_eq!(r.stats.cycles, reference.stats.cycles, "{name}: cycles");
        }
    }
    // A 1-deep credit loop on a shared ring must visibly stall…
    let starved = topo(
        Sim::scenario("ring", &cfg(configs[0].1)).unwrap(),
        1,
        SchedMode::FullScan,
    )
    .run()
    .unwrap();
    assert!(
        starved.stats.counters.get("flow.credits_stalled") > 0,
        "credits=1 on a 6-node ring must stall"
    );
    // …while the uncredited baseline never reports one.
    let open = topo(
        Sim::scenario("ring", &cfg(&[("nodes", "6"), ("packets", "8")])).unwrap(),
        1,
        SchedMode::FullScan,
    )
    .run()
    .unwrap();
    assert_eq!(open.stats.counters.get("flow.credits_stalled"), 0);
}
