//! The golden-fingerprint harness (ISSUE 5): every registered scenario is
//! pinned at a fixed small config, and its serial fingerprint is checked
//! into `rust/tests/golden_fingerprints.txt`. Any semantic drift — a
//! scheduling change that alters *when* a unit runs, a scenario edit, an
//! engine bug — shows up as a pin mismatch, while pure performance work
//! (partitioning, sleep/wake, repartitioning cadence) must keep every pin
//! bit-identical.
//!
//! On top of the pins, every scenario is re-run under the ladder engine
//! with repartitioning off, fixed-cadence, and drift-adaptive policies;
//! all three must reproduce the serial fingerprint and cycle count. That
//! parity holds (and is enforced) even while a pin is still `pending`.
//!
//! Regenerate the pins after an *intended* semantic change:
//!
//! ```text
//! UPDATE_GOLDEN=1 cargo test -q --test golden
//! ```

use scalesim::engine::{Engine, RepartitionPolicy, Sim};
use scalesim::scenario;
use scalesim::util::config::Config;
use std::collections::BTreeMap;
use std::fmt::Write as _;

const GOLDEN_PATH: &str = concat!(
    env!("CARGO_MANIFEST_DIR"),
    "/rust/tests/golden_fingerprints.txt"
);

const HEADER: &str = "\
# Golden serial fingerprints for every registered scenario at the pinned
# small configs in rust/tests/golden.rs (`pinned_config`).
#
# Format: <scenario> <fingerprint> <cycles>
# Regenerate: UPDATE_GOLDEN=1 cargo test -q --test golden
#
# An entry of `pending pending` means the pin has not been captured on
# the reference machine yet; golden.rs still enforces serial == parallel
# == repartitioned == adaptive on every run, and prints the value to pin.
";

/// The fixed small config each scenario is pinned at. Every registered
/// scenario must have an arm here — the panic keeps the golden suite
/// honest when a new scenario lands.
fn pinned_config(name: &str) -> Config {
    let mut c = Config::new();
    match name {
        "pipeline" => {
            c.set("stages", 5);
            c.set("messages", 20);
        }
        "cpu-light" => {
            c.set("cores", 2);
            c.set("txns", 20);
        }
        "cpu-ooo" => {
            c.set("cores", 2);
            c.set("txns", 2);
        }
        "fat-tree" => {
            c.set("k", 4);
            c.set("packets", 120);
            c.set("window", 30);
        }
        "mesh" => {
            c.set("width", 2);
            c.set("height", 2);
            c.set("packets", 8);
        }
        "ring" => {
            c.set("nodes", 6);
            c.set("packets", 8);
        }
        "torus" => {
            c.set("dim", 3);
            c.set("packets", 6);
        }
        "tree" => {
            c.set("fanout", 2);
            c.set("depth", 3);
            c.set("packets", 8);
        }
        "incast" => {
            c.set("hosts", 4);
            c.set("packets", 6);
            c.set("credits", 2);
            c.set("burst", "4:4");
        }
        other => panic!(
            "scenario {other:?} has no pinned golden config — add an arm to \
             pinned_config() and regenerate with UPDATE_GOLDEN=1"
        ),
    }
    c
}

/// A parsed golden entry; `None` = the `pending` placeholder.
struct Pin {
    fingerprint: Option<u64>,
    cycles: Option<u64>,
}

fn load_pins(update: bool) -> BTreeMap<String, Pin> {
    let text = match std::fs::read_to_string(GOLDEN_PATH) {
        Ok(t) => t,
        Err(e) if update => {
            eprintln!("golden: {GOLDEN_PATH} unreadable ({e}); regenerating from scratch");
            return BTreeMap::new();
        }
        Err(e) => panic!("golden: cannot read {GOLDEN_PATH}: {e}"),
    };
    let mut pins = BTreeMap::new();
    for (lineno, line) in text.lines().enumerate() {
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let mut parts = line.split_whitespace();
        let (Some(name), Some(fp), Some(cycles)) = (parts.next(), parts.next(), parts.next())
        else {
            panic!("golden: line {} is malformed: {line:?}", lineno + 1);
        };
        let parse_hex = |s: &str| {
            u64::from_str_radix(s.trim_start_matches("0x"), 16)
                .unwrap_or_else(|e| panic!("golden: line {}: bad value {s:?}: {e}", lineno + 1))
        };
        let pin = if fp == "pending" {
            Pin {
                fingerprint: None,
                cycles: None,
            }
        } else {
            Pin {
                fingerprint: Some(parse_hex(fp)),
                cycles: Some(
                    cycles
                        .parse()
                        .unwrap_or_else(|e| panic!("golden: line {}: {e}", lineno + 1)),
                ),
            }
        };
        pins.insert(name.to_string(), pin);
    }
    pins
}

/// The ladder-side policies every scenario must reproduce the serial
/// fingerprint under: plain parallel, migration-happy fixed cadence, and
/// migration-happy drift-adaptive cadence.
fn parity_policies() -> [(&'static str, RepartitionPolicy); 3] {
    [
        ("parallel", RepartitionPolicy::Off),
        (
            "fixed-repartition",
            RepartitionPolicy::Fixed {
                interval_cycles: 16,
                hysteresis: 0.0,
                max_moves: usize::MAX,
            },
        ),
        (
            "adaptive-repartition",
            RepartitionPolicy::Adaptive {
                check_every: 8,
                drift_threshold: 0.0,
                backoff: 2,
                hysteresis: 0.0,
                max_moves: usize::MAX,
            },
        ),
    ]
}

#[test]
fn golden_fingerprints_pin_every_scenario() {
    let update = std::env::var("UPDATE_GOLDEN").is_ok_and(|v| !v.is_empty() && v != "0");
    let pins = load_pins(update);
    let names = scenario::names();
    if !update {
        for name in &names {
            assert!(
                pins.contains_key(*name),
                "scenario {name:?} is missing from golden_fingerprints.txt — \
                 regenerate with UPDATE_GOLDEN=1 cargo test -q --test golden"
            );
        }
        for key in pins.keys() {
            assert!(
                names.contains(&key.as_str()),
                "golden_fingerprints.txt pins unknown scenario {key:?} — remove the line"
            );
        }
    }

    let mut regenerated = String::new();
    for name in &names {
        let cfg = pinned_config(name);
        let serial = Sim::scenario(name, &cfg)
            .unwrap()
            .fingerprinted()
            .run()
            .unwrap();
        let (fp, cycles) = (serial.fingerprint(), serial.stats.cycles);
        assert_ne!(fp, 0, "{name}: fingerprint must be computed");
        if update {
            writeln!(regenerated, "{name} {fp:#018x} {cycles}").unwrap();
        } else {
            let pin = &pins[*name];
            match pin.fingerprint {
                Some(pinned) => {
                    assert_eq!(
                        fp, pinned,
                        "{name}: serial fingerprint {fp:#018x} drifted from the pinned \
                         golden value {pinned:#018x} — if this semantic change is \
                         intended, regenerate with UPDATE_GOLDEN=1 cargo test -q --test \
                         golden"
                    );
                    assert_eq!(
                        Some(cycles),
                        pin.cycles,
                        "{name}: cycle count drifted from the pin"
                    );
                }
                None => eprintln!(
                    "golden: {name} is unpinned — UPDATE_GOLDEN=1 would pin \
                     {fp:#018x} @ {cycles} cycles"
                ),
            }
        }

        // Parallel / repartition / adaptive parity against the serial
        // value — enforced regardless of the pin's state.
        for (label, policy) in parity_policies() {
            let r = Sim::scenario(name, &cfg)
                .unwrap()
                .workers(2)
                .repartition(policy)
                .fingerprinted()
                .engine(Engine::Ladder)
                .run()
                .unwrap();
            assert_eq!(
                r.fingerprint(),
                fp,
                "{name}/{label}: ladder run diverged from the serial fingerprint"
            );
            assert_eq!(r.stats.cycles, cycles, "{name}/{label}: cycle count diverged");
        }
    }

    if update {
        std::fs::write(GOLDEN_PATH, format!("{HEADER}{regenerated}"))
            .unwrap_or_else(|e| panic!("golden: cannot write {GOLDEN_PATH}: {e}"));
        eprintln!("golden: rewrote {GOLDEN_PATH}");
    }
}

/// Scenarios whose units all implement state snapshots (ISSUE 6).
/// `cpu-ooo` and `fat-tree` opt out (`snapshot_supported()` is false)
/// and are rejected by `checkpoint_every` up front, so they are excluded
/// here rather than silently skipped.
const SNAPSHOT_SCENARIOS: [&str; 7] = [
    "pipeline", "cpu-light", "mesh", "ring", "torus", "tree", "incast",
];

/// Checkpoint/restore is held to the same bar as the ladder policies:
/// interrupting a pinned run halfway through and resuming from the
/// snapshot must reproduce the uninterrupted serial fingerprint and
/// cycle count bit-for-bit.
#[test]
fn golden_checkpoint_restore_parity() {
    let names = scenario::names();
    for name in SNAPSHOT_SCENARIOS {
        assert!(
            names.contains(&name),
            "golden: SNAPSHOT_SCENARIOS lists unknown scenario {name:?}"
        );
        let cfg = pinned_config(name);
        let full = Sim::scenario(name, &cfg)
            .unwrap()
            .fingerprinted()
            .run()
            .unwrap();
        let total = full.stats.cycles;
        assert!(
            total >= 4,
            "{name}: pinned run is too short ({total} cycles) to interrupt"
        );
        let half = total / 2;
        let path = std::env::temp_dir().join(format!(
            "golden-checkpoint-{name}-{}.snap",
            std::process::id()
        ));

        // Interrupted run: stop at the halfway barrier, which is also a
        // checkpoint cycle (checkpoints are written before the stop check).
        let truncated = Sim::scenario(name, &cfg)
            .unwrap()
            .cycles(half)
            .checkpoint_every(half, &path)
            .fingerprinted()
            .run()
            .unwrap();
        assert_eq!(
            truncated.stats.cycles, half,
            "{name}: interrupted run did not stop at the checkpoint cycle"
        );

        let resumed = Sim::restore(&path).unwrap().fingerprinted().run().unwrap();
        let _ = std::fs::remove_file(&path);
        assert_eq!(
            resumed.fingerprint(),
            full.fingerprint(),
            "{name}: restored run's fingerprint diverged from the uninterrupted run"
        );
        assert_eq!(
            resumed.stats.cycles, total,
            "{name}: restored run's final cycle diverged from the uninterrupted run"
        );
    }
}
