//! Mid-run repartitioning (ISSUE 3 + ISSUE 5) and transfer-phase
//! sleep/wake, end to end:
//!
//! 1. A migration stress: per-unit costs flip mid-run, the policy must
//!    actually move units (`repartition_events > 0`) — and the simulated
//!    execution must stay bit-identical to the serial reference, because
//!    migration changes *where* a unit runs, never *when*.
//! 2. The drift-adaptive cadence: on the same cost flip, the adaptive
//!    policy must still migrate, reach at least the fixed-interval
//!    policy's imbalance improvement, and run strictly fewer full
//!    planner evaluations (`repartition_checks`) — that is the saving
//!    the drift signal exists to buy.
//! 3. Port parking: a port blocked on a stalling receiver leaves the
//!    dirty list and comes back through the receiver-vacancy wake, so the
//!    transfer phase stops re-walking it every cycle.
//!
//! The phased cost model lives in `tests/common`.

mod common;

use common::{phased_model, phased_start_partition};
use scalesim::engine::{
    Ctx, Engine, Fnv, In, Model, ModelBuilder, Msg, Out, PortCfg, RepartitionPolicy, RunOpts,
    SchedMode, Sim, Transit, Unit,
};
use scalesim::util::config::Config;

// ---------------------------------------------------------------------
// Migration stress: cost flip mid-run
// ---------------------------------------------------------------------

#[test]
fn cost_flip_triggers_migration_and_preserves_fingerprints() {
    let cycles = 3_000;
    let flip_at = 1_500;
    let reference = phased_model(flip_at).run_serial(RunOpts::cycles(cycles).fingerprinted());

    // All heavy units start on cluster 0: massively imbalanced, so the
    // first barrier decision must migrate (heavy/light cost ratio is
    // ~1000x — far beyond any timing noise).
    let report = Sim::from_model(phased_model(flip_at))
        .partition(phased_start_partition())
        .repartition(RepartitionPolicy::every(100))
        .cycles(cycles)
        .fingerprinted()
        .engine(Engine::Ladder)
        .run()
        .expect("ladder run");
    assert!(
        report.repartition_events() >= 1,
        "imbalanced start + cost flip must migrate: {:?}",
        report.stats.repart
    );
    assert_eq!(
        report.fingerprint(),
        reference.fingerprint,
        "migration must be semantically invisible"
    );
    assert_eq!(report.stats.cycles, cycles);
    // The epochs record what the decision saw: a real improvement, at
    // least one unit moved, and a full projected cost vector.
    let first = &report.stats.repart.epochs[0];
    assert!(first.moves >= 1);
    assert!(
        first.imbalance_before > first.imbalance_after,
        "recorded imbalance must improve: {first:?}"
    );
    assert_eq!(first.cluster_costs.len(), 2);
    // The run ended on a different mapping than it started.
    assert_eq!(report.partition, vec![vec![0, 1, 2, 3], vec![4, 5, 6, 7]]);
    assert_ne!(report.final_partition(), report.partition.as_slice());
    assert_eq!(
        report.final_partition().iter().map(|c| c.len()).sum::<usize>(),
        8,
        "final mapping still covers every unit"
    );
}

#[test]
fn max_moves_caps_each_epoch() {
    let cycles = 2_000;
    let reference = phased_model(1_000).run_serial(RunOpts::cycles(cycles).fingerprinted());
    let policy = RepartitionPolicy::Fixed {
        interval_cycles: 100,
        hysteresis: 0.05,
        max_moves: 1,
    };
    let report = Sim::from_model(phased_model(1_000))
        .partition(phased_start_partition())
        .repartition(policy)
        .cycles(cycles)
        .fingerprinted()
        .engine(Engine::Ladder)
        .run()
        .expect("ladder run");
    assert!(report.repartition_events() >= 1);
    assert!(
        report.stats.repart.epochs.iter().all(|e| e.moves <= 1),
        "max_moves=1 violated: {:?}",
        report.stats.repart.epochs
    );
    assert_eq!(report.fingerprint(), reference.fingerprint);
}

#[test]
fn adaptive_cadence_migrates_with_fewer_planner_runs_than_fixed() {
    let cycles = 3_000;
    let flip_at = 1_500;
    let reference = phased_model(flip_at).run_serial(RunOpts::cycles(cycles).fingerprinted());
    let run = |policy: RepartitionPolicy| {
        Sim::from_model(phased_model(flip_at))
            .partition(phased_start_partition())
            .repartition(policy)
            .cycles(cycles)
            .fingerprinted()
            .engine(Engine::Ladder)
            .run()
            .expect("ladder run")
    };
    // Same decision cadence (100 cycles); the policies differ only in
    // when they pay for a full plan.
    let fixed = run(RepartitionPolicy::every(100));
    let adaptive = run(RepartitionPolicy::Adaptive {
        check_every: 100,
        drift_threshold: 0.25,
        backoff: 2,
        hysteresis: 0.05,
        max_moves: usize::MAX,
    });

    // Serial parity throughout: the cadence policy is a performance knob,
    // never a semantic one.
    assert_eq!(fixed.fingerprint(), reference.fingerprint);
    assert_eq!(adaptive.fingerprint(), reference.fingerprint);
    assert_eq!(adaptive.stats.cycles, cycles);

    // The drift must actually trigger: the start partition is ~1000x
    // imbalanced, far past the 0.25 drift threshold.
    assert!(
        adaptive.repartition_events() >= 1,
        "adaptive must migrate on the skew: {:?}",
        adaptive.stats.repart
    );
    assert!(fixed.repartition_events() >= 1);

    // The headline saving: both policies probed ~cycles/100 times, but
    // the fixed policy ran the full planner at every probe while the
    // adaptive one planned only when the smoothed drift crossed the
    // threshold.
    let f = &fixed.stats.repart;
    let a = &adaptive.stats.repart;
    assert_eq!(f.checks, f.probes, "fixed: every probe is a full plan");
    assert!(
        a.checks < f.checks,
        "adaptive must run strictly fewer planner evaluations: \
         adaptive {}/{} (plans/probes) vs fixed {}/{}",
        a.checks,
        a.probes,
        f.checks,
        f.probes
    );
    assert!(a.probes >= f.checks / 2, "same cadence: probes stay cheap, not absent");

    // And it must not trade away balance: the best migration epoch's
    // imbalance improvement reaches the fixed policy's (0.1 of slack for
    // wall-clock sampling noise — the skew itself is ~1.0 of max/mean).
    let best = |r: &scalesim::stats::RepartStats| {
        r.epochs
            .iter()
            .map(|e| e.imbalance_before - e.imbalance_after)
            .fold(0.0f64, f64::max)
    };
    let fixed_gain = best(f);
    let adaptive_gain = best(a);
    assert!(
        adaptive_gain >= fixed_gain - 0.1,
        "adaptive improvement {adaptive_gain:.3} must reach fixed {fixed_gain:.3}"
    );
    assert!(
        adaptive_gain > 0.5,
        "the ~2.0 starting imbalance must really have been rebalanced: \
         {adaptive_gain:.3}"
    );
}

#[test]
fn scenario_config_key_drives_repartitioning() {
    let mut cfg = Config::new();
    cfg.set("stages", 6);
    cfg.set("messages", 40);
    cfg.set("cycles", 300);
    let reference = Sim::scenario("pipeline", &cfg)
        .unwrap()
        .fingerprinted()
        .run()
        .unwrap();
    cfg.set("repartition", "16,0.0");
    let r = Sim::scenario("pipeline", &cfg)
        .unwrap()
        .workers(2)
        .sched(SchedMode::ActiveList)
        .fingerprinted()
        .run()
        .unwrap();
    assert_eq!(r.fingerprint(), reference.fingerprint());
    assert!(
        r.stats.repart.checks >= 1,
        "the config key must reach the ladder: {:?}",
        r.stats.repart
    );
    // The adaptive spelling reaches the ladder the same way.
    cfg.set("repartition", "adaptive,0.0,16");
    let r = Sim::scenario("pipeline", &cfg)
        .unwrap()
        .workers(2)
        .fingerprinted()
        .run()
        .unwrap();
    assert_eq!(r.fingerprint(), reference.fingerprint());
    assert!(
        r.stats.repart.probes >= 1,
        "the adaptive key must reach the ladder: {:?}",
        r.stats.repart
    );
    // A malformed spec fails the session build, not the run.
    cfg.set("repartition", "not-a-number");
    assert!(Sim::scenario("pipeline", &cfg).is_err());
}

// ---------------------------------------------------------------------
// Transfer-phase sleep/wake: blocked ports park behind a vacancy wake
// ---------------------------------------------------------------------

/// Sends `limit` messages as fast as back pressure allows.
struct Flood {
    out: Out<Transit>,
    sent: u64,
    limit: u64,
}

impl Unit for Flood {
    fn work(&mut self, ctx: &mut Ctx<'_>) {
        if self.sent < self.limit && self.out.vacant(ctx) {
            self.out
                .send_msg(ctx, Msg::with(1, self.sent, 0, 0))
                .unwrap();
            self.sent += 1;
        }
    }

    fn state_hash(&self, h: &mut Fnv) {
        h.write_u64(self.sent);
    }

    fn is_idle(&self) -> bool {
        self.sent >= self.limit
    }
}

/// Consumes only every 8th cycle — the port upstream spends most of its
/// life blocked on a full receiver queue.
struct SlowDrain {
    inp: In<Transit>,
    received: u64,
}

impl Unit for SlowDrain {
    fn work(&mut self, ctx: &mut Ctx<'_>) {
        if ctx.cycle % 8 == 0 {
            while let Some(m) = self.inp.recv_msg(ctx) {
                assert_eq!(m.a, self.received, "FIFO broken");
                self.received += 1;
            }
        }
    }

    fn state_hash(&self, h: &mut Fnv) {
        h.write_u64(self.received);
    }
}

fn blocked_pipeline(limit: u64) -> Model {
    let mut mb = ModelBuilder::new();
    let a = mb.reserve_unit("flood");
    let b = mb.reserve_unit("slow");
    let (tx, rx) = mb.link::<Transit>(a, b, PortCfg::new(1, 1));
    mb.install(
        a,
        Box::new(Flood {
            out: tx,
            sent: 0,
            limit,
        }),
    );
    mb.install(b, Box::new(SlowDrain { inp: rx, received: 0 }));
    mb.build().unwrap()
}

#[test]
fn blocked_ports_park_instead_of_rewalking() {
    let cycles = 320;
    let full = blocked_pipeline(30).run_serial(RunOpts::cycles(cycles).fingerprinted());
    let active =
        blocked_pipeline(30).run_serial(RunOpts::cycles(cycles).fingerprinted().active_list());
    assert_eq!(
        active.fingerprint, full.fingerprint,
        "port parking must be semantically invisible"
    );
    let full_walks = full.per_worker[0].port_walks;
    let active_walks = active.per_worker[0].port_walks;
    // Full scan re-walks the blocked port every cycle (~cycles walks);
    // parking wakes it only when the receiver actually frees a slot.
    assert!(
        active_walks < full_walks / 2,
        "parking must cut port walks: active={active_walks} full={full_walks}"
    );
    assert!(full_walks > 200, "sanity: the port really was hot-blocked");
}

#[test]
fn port_parking_holds_across_engines_and_workers() {
    let cycles = 320;
    let reference = blocked_pipeline(30).run_serial(RunOpts::cycles(cycles).fingerprinted());
    for workers in [1usize, 2] {
        for sched in [SchedMode::FullScan, SchedMode::ActiveList] {
            let r = Sim::from_model(blocked_pipeline(30))
                .workers(workers)
                .sched(sched)
                .cycles(cycles)
                .fingerprinted()
                .engine(Engine::Ladder)
                .run()
                .expect("ladder run");
            assert_eq!(
                r.fingerprint(),
                reference.fingerprint,
                "workers={workers} sched={}",
                sched.name()
            );
        }
    }
}
