//! Crash-resilience suite: barrier checkpoint/restore fingerprint parity,
//! deterministic fault injection, and the stall watchdog.
//!
//! The hard invariant under test: a run that is checkpointed, killed, and
//! restored must finish with a fingerprint bit-identical to an
//! uninterrupted run — across the serial and ladder engines, both
//! scheduling modes, and with mid-run repartitioning live.

mod common;

use std::path::PathBuf;

use scalesim::engine::{Engine, FaultPlan, SchedMode, Sim, Watchdog};
use scalesim::util::config::Config;

fn cfg(pairs: &[(&str, &str)]) -> Config {
    let mut c = Config::new();
    for (k, v) in pairs {
        c.set(k, v);
    }
    c
}

/// Unique-per-test snapshot path (the suite runs tests concurrently).
fn snap_path(tag: &str) -> PathBuf {
    std::env::temp_dir().join(format!("scalesim_{}_{}.snap", tag, std::process::id()))
}

/// Apply one engine-topology cell to a session.
fn topo(sim: Sim, workers: usize, sched: SchedMode) -> Sim {
    let engine = if workers <= 1 {
        Engine::Serial
    } else {
        Engine::Ladder
    };
    sim.workers(workers).engine(engine).sched(sched).fingerprinted()
}

/// The tentpole invariant: full run vs checkpoint → kill → restore.
///
/// The "kill" is a truncated session (`.cycles(interrupt_at)`) that stops
/// right after writing its last snapshot; the restore rebuilds the
/// scenario from the snapshot's meta block and runs to the config's own
/// stop condition.
fn assert_checkpoint_restore_parity(
    tag: &str,
    scenario: &str,
    pairs: &[(&str, &str)],
    workers: usize,
    sched: SchedMode,
    every: u64,
    interrupt_at: u64,
) {
    let c = cfg(pairs);
    let full = topo(Sim::scenario(scenario, &c).unwrap(), workers, sched)
        .run()
        .unwrap_or_else(|e| panic!("{tag}: full run: {e}"));
    assert_ne!(full.fingerprint(), 0, "{tag}: fingerprint not computed");

    let path = snap_path(tag);
    let interrupted = topo(Sim::scenario(scenario, &c).unwrap(), workers, sched)
        .cycles(interrupt_at)
        .checkpoint_every(every, &path)
        .run()
        .unwrap_or_else(|e| panic!("{tag}: interrupted run: {e}"));
    assert_eq!(interrupted.stats.cycles, interrupt_at, "{tag}: truncated stop");
    assert!(path.exists(), "{tag}: no snapshot written");

    let restored = topo(Sim::restore(&path).unwrap(), workers, sched)
        .run()
        .unwrap_or_else(|e| panic!("{tag}: restored run: {e}"));
    let _ = std::fs::remove_file(&path);

    assert_eq!(
        restored.fingerprint(),
        full.fingerprint(),
        "{tag}: restored fingerprint diverged from the uninterrupted run"
    );
    assert_eq!(restored.stats.cycles, full.stats.cycles, "{tag}: cycle count");
}

const PIPELINE_CFG: &[(&str, &str)] = &[("stages", "6"), ("messages", "400"), ("cycles", "200")];

#[test]
fn pipeline_parity_serial_and_ladder() {
    for (i, &(workers, sched)) in [
        (1, SchedMode::FullScan),
        (1, SchedMode::ActiveList),
        (2, SchedMode::FullScan),
        (2, SchedMode::ActiveList),
        (4, SchedMode::ActiveList),
    ]
    .iter()
    .enumerate()
    {
        // every=40 with the kill at 100: the file is written at 40 then
        // overwritten at 80, so the restore also proves snapshot
        // overwrite + resume-from-non-kill-cycle.
        assert_checkpoint_restore_parity(
            &format!("pipeline_{i}"),
            "pipeline",
            PIPELINE_CFG,
            workers,
            sched,
            40,
            100,
        );
    }
}

#[test]
fn pipeline_parity_with_repartitioning() {
    for (i, spec) in ["50", "adaptive"].iter().enumerate() {
        let pairs = [
            ("stages", "6"),
            ("messages", "400"),
            ("cycles", "200"),
            ("repartition", spec),
        ];
        // The repartition policy rides in the scenario config, so the
        // restored session re-arms it; the snapshot carries the live
        // partition and the repartitioner's EWMA/backoff resume state.
        assert_checkpoint_restore_parity(
            &format!("pipeline_repart_{i}"),
            "pipeline",
            &pairs,
            2,
            SchedMode::ActiveList,
            50,
            100,
        );
    }
}

#[test]
fn cpu_light_parity() {
    let pairs = [
        ("cores", "4"),
        ("txns", "20"),
        ("rows", "128"),
        ("cycles", "400"),
    ];
    for (i, &(workers, sched)) in [(1, SchedMode::FullScan), (2, SchedMode::ActiveList)]
        .iter()
        .enumerate()
    {
        assert_checkpoint_restore_parity(
            &format!("cpu_light_{i}"),
            "cpu-light",
            &pairs,
            workers,
            sched,
            200,
            200,
        );
    }
}

#[test]
fn torus_parity() {
    let pairs = [("dim", "3"), ("packets", "8"), ("cycles", "240")];
    assert_checkpoint_restore_parity(
        "torus",
        "torus",
        &pairs,
        2,
        SchedMode::ActiveList,
        120,
        120,
    );
}

#[test]
fn tree_parity() {
    let pairs = [
        ("fanout", "2"),
        ("depth", "3"),
        ("packets", "8"),
        ("cycles", "240"),
    ];
    assert_checkpoint_restore_parity(
        "tree",
        "tree",
        &pairs,
        2,
        SchedMode::ActiveList,
        120,
        120,
    );
}

#[test]
fn serial_checkpoint_resumes_on_the_ladder() {
    // Engine topology is an execution choice, not simulation state: a
    // snapshot written by the serial engine restores onto a 2-worker
    // ladder with an identical final fingerprint.
    let c = cfg(PIPELINE_CFG);
    let full = topo(Sim::scenario("pipeline", &c).unwrap(), 1, SchedMode::FullScan)
        .run()
        .unwrap();

    let path = snap_path("cross_topology");
    topo(Sim::scenario("pipeline", &c).unwrap(), 1, SchedMode::FullScan)
        .cycles(100)
        .checkpoint_every(100, &path)
        .run()
        .unwrap();

    let restored = topo(Sim::restore(&path).unwrap(), 2, SchedMode::ActiveList)
        .run()
        .unwrap();
    let _ = std::fs::remove_file(&path);
    assert_eq!(restored.fingerprint(), full.fingerprint());
    assert_eq!(restored.engine, "ladder");
}

#[test]
fn checkpoint_requires_a_scenario_session() {
    let err = Sim::from_model(common::sleepy_pipeline(4, 10))
        .cycles(50)
        .checkpoint_every(10, snap_path("no_scenario"))
        .run()
        .unwrap_err();
    assert!(err.contains("requires a scenario session"), "{err}");
}

#[test]
fn unsupported_scenario_is_rejected_up_front() {
    // The OOO core opts out of persistence; checkpointing must fail with
    // a clear error before the run starts, not corrupt a snapshot.
    let c = cfg(&[("cores", "2"), ("txns", "2"), ("cycles", "50")]);
    let err = Sim::scenario("cpu-ooo", &c)
        .unwrap()
        .checkpoint_every(10, snap_path("ooo"))
        .run()
        .unwrap_err();
    assert!(err.contains("does not support state snapshots"), "{err}");
}

#[test]
fn restore_rejects_corrupt_snapshots() {
    let path = snap_path("corrupt");
    std::fs::write(&path, b"definitely not a snapshot").unwrap();
    let err = Sim::restore(&path).map(|_| ()).unwrap_err();
    let _ = std::fs::remove_file(&path);
    assert!(err.contains("bad magic") || err.contains("too short"), "{err}");
}

#[test]
fn partitioned_engine_rejects_supervision() {
    let c = cfg(PIPELINE_CFG);
    let err = Sim::scenario("pipeline", &c)
        .unwrap()
        .workers(2)
        .engine(Engine::Partitioned)
        .inject(FaultPlan::new().panic_at(10, 0))
        .run()
        .unwrap_err();
    assert!(err.contains("partitioned serial engine"), "{err}");
}

// ---------------------------------------------------------------------
// Fault injection: panics surface as structured SimErrors, nothing hangs
// ---------------------------------------------------------------------

#[test]
fn injected_panic_serial_is_structured() {
    let c = cfg(PIPELINE_CFG);
    let err = Sim::scenario("pipeline", &c)
        .unwrap()
        .engine(Engine::Serial)
        .inject(FaultPlan::new().panic_at(20, 2))
        .run()
        .unwrap_err();
    assert!(err.contains("SimError at cycle 20"), "{err}");
    assert!(err.contains("unit 2"), "{err}");
}

#[test]
fn injected_panic_ladder_unwinds_all_workers() {
    // The worker that owns unit 2 panics mid-work; every other worker must
    // drain through the barrier protocol and join cleanly (a hang here
    // fails the suite on its timeout), and the error must carry the
    // cycle, the unit, and a barrier diagnostic.
    for sched in [SchedMode::FullScan, SchedMode::ActiveList] {
        let c = cfg(PIPELINE_CFG);
        let err = Sim::scenario("pipeline", &c)
            .unwrap()
            .workers(2)
            .engine(Engine::Ladder)
            .sched(sched)
            .inject(FaultPlan::new().panic_at(20, 2))
            .run()
            .unwrap_err();
        assert!(err.contains("SimError at cycle 20"), "{err}");
        assert!(err.contains("unit 2"), "{err}");
        assert!(err.contains("work phase"), "{err}");
        assert!(err.contains("diagnostic"), "{err}");
    }
}

#[test]
fn injected_panic_ladder_four_workers() {
    let c = cfg(PIPELINE_CFG);
    let err = Sim::scenario("pipeline", &c)
        .unwrap()
        .workers(4)
        .engine(Engine::Ladder)
        .sched(SchedMode::ActiveList)
        .inject(FaultPlan::new().panic_at(30, 5))
        .run()
        .unwrap_err();
    assert!(err.contains("SimError at cycle 30"), "{err}");
    assert!(err.contains("unit 5"), "{err}");
}

// ---------------------------------------------------------------------
// Stall watchdog: a lost wakeup is named, not spun on
// ---------------------------------------------------------------------

#[test]
fn watchdog_names_the_parked_unit_serial() {
    // Force-park the sink (last stage) before its traffic arrives: the
    // synthetic lost wakeup. Two messages fit the final port's queue, so
    // the upstream stages drain and park too — the next epoch ticks zero
    // units with messages still queued. Without the watchdog this would
    // spin to the cycle cap doing nothing.
    let c = cfg(&[("stages", "4"), ("messages", "2"), ("cycles", "5000")]);
    let err = Sim::scenario("pipeline", &c)
        .unwrap()
        .engine(Engine::Serial)
        .sched(SchedMode::ActiveList)
        .inject(FaultPlan::new().stall_at(2, 3))
        .run()
        .unwrap_err();
    assert!(err.contains("lost wakeup"), "{err}");
    assert!(err.contains("3 ("), "{err}: should name unit 3");
}

#[test]
fn watchdog_names_the_parked_unit_ladder() {
    let c = cfg(&[("stages", "4"), ("messages", "2"), ("cycles", "5000")]);
    let err = Sim::scenario("pipeline", &c)
        .unwrap()
        .workers(2)
        .engine(Engine::Ladder)
        .sched(SchedMode::ActiveList)
        .inject(FaultPlan::new().stall_at(2, 3))
        .run()
        .unwrap_err();
    assert!(err.contains("lost wakeup"), "{err}");
    assert!(err.contains("3 ("), "{err}: should name unit 3");
}

#[test]
fn watchdog_epoch_budget_trips_on_injected_delay() {
    let c = cfg(PIPELINE_CFG);
    let err = Sim::scenario("pipeline", &c)
        .unwrap()
        .workers(2)
        .engine(Engine::Ladder)
        .inject(FaultPlan::new().delay_at(10, 0, 100))
        .watchdog(Watchdog {
            epoch_budget_ms: Some(10),
            ..Watchdog::default()
        })
        .run()
        .unwrap_err();
    assert!(err.contains("budget"), "{err}");
}

#[test]
fn fault_plan_parse_roundtrip() {
    let plan = FaultPlan::parse("panic@120:3, stall@40:1, delay@50:0:200").unwrap();
    assert!(!plan.is_empty());
    assert!(FaultPlan::parse("panic@120").is_err());
    assert!(FaultPlan::parse("explode@1:2").is_err());
    assert!(FaultPlan::parse("delay@1:2").is_err());
}
