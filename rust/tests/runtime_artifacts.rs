//! Integration: the AOT artifacts (python/jax/pallas → HLO text) executed
//! from rust via PJRT must agree with the native implementations.
//!
//! Requires `make artifacts`. Tests soft-skip (with a loud message) when
//! the artifacts directory is absent so `cargo test` stays runnable before
//! the first build; the Makefile always builds artifacts first.
//!
//! The whole target is additionally gated on the `pjrt` feature (see
//! Cargo.toml `required-features`): without it the crate has no runtime
//! module at all, keeping the default build dependency-free.
#![cfg(feature = "pjrt")]

use scalesim::dc::traffic::{packet, TrafficCfg};
use scalesim::explore;
use scalesim::runtime::artifacts::{Artifacts, FABRIC_B};
use scalesim::runtime::Runtime;

fn load() -> Option<(Runtime, Artifacts)> {
    let dir = scalesim::runtime::artifacts::artifacts_dir();
    if !dir.join("traffic.hlo.txt").exists() {
        eprintln!(
            "SKIP: artifacts not found in {} — run `make artifacts`",
            dir.display()
        );
        return None;
    }
    let rt = Runtime::cpu().expect("PJRT CPU client");
    let arts = Artifacts::load(&rt, &dir).expect("load artifacts");
    Some((rt, arts))
}

#[test]
fn traffic_artifact_matches_native_bit_for_bit() {
    let Some((_rt, arts)) = load() else { return };
    let cfg = TrafficCfg {
        seed: 0xDC,
        hosts: 1024,
        packets: 0, // unused here
        inject_window: 10_000,
    };
    let pkts = arts
        .traffic
        .generate(cfg.seed, cfg.hosts, cfg.inject_window)
        .expect("run traffic artifact");
    assert_eq!(pkts.len(), scalesim::runtime::artifacts::TRAFFIC_N);
    for i in [0usize, 1, 7, 100, 4096, 65_535] {
        let native = packet(&cfg, i as u64);
        assert_eq!(pkts[i].src, native.src, "src of packet {i}");
        assert_eq!(pkts[i].dst, native.dst, "dst of packet {i}");
        assert_eq!(pkts[i].inject_cycle, native.inject_cycle, "cycle of {i}");
    }
    // Full-range equality.
    for (i, p) in pkts.iter().enumerate() {
        let native = packet(&cfg, i as u64);
        assert_eq!((p.src, p.dst, p.inject_cycle), (native.src, native.dst, native.inject_cycle));
    }
}

#[test]
fn fabric_artifact_latency_is_sane_and_monotone_in_load() {
    let Some((_rt, arts)) = load() else { return };
    let mut low = [[16.0f32, 0.05, 8.0, 1.0, 1.0]; FABRIC_B];
    let mut high = low;
    for r in &mut high {
        r[1] = 0.9;
    }
    let _ = &mut low;
    let lo = arts.fabric.latency(&low).unwrap()[0];
    let hi = arts.fabric.latency(&high).unwrap()[0];
    assert!(lo > 8.0 && lo < 14.0, "unloaded k=16 ≈ hop latency: {lo}");
    assert!(hi > lo + 1.0, "load must raise latency: {lo} → {hi}");
}

#[test]
fn gradient_descent_reduces_objective() {
    let Some((_rt, arts)) = load() else { return };
    let init = explore::seed_batch(16.0, 1.0, 1.0);
    let res = explore::gradient_descent(&arts.fabric_grad, init, 30, 0.05).unwrap();
    let first = res.objective_history[0];
    let last = *res.objective_history.last().unwrap();
    assert!(
        last < first,
        "objective should decrease: {first} → {last} ({:?})",
        res.objective_history
    );
    // All params stayed in bounds.
    for row in &res.params {
        for d in 0..5 {
            assert!(row[d] >= explore::LO[d] - 1e-5 && row[d] <= explore::HI[d] + 1e-5);
        }
    }
}

#[test]
fn surrogate_tracks_cycle_accurate_simulation() {
    let Some((_rt, arts)) = load() else { return };
    // Two design points: light load and heavy load on k=4. The surrogate
    // must get the *ordering* and rough magnitude right (it's a queueing
    // approximation, not a re-implementation).
    let light = explore::cross_validate(&arts.fabric, [4.0, 0.1, 4.0, 1.0, 1.0], 2_000, 7)
        .expect("light validation");
    let heavy = explore::cross_validate(&arts.fabric, [4.0, 0.7, 4.0, 1.0, 1.0], 2_000, 7)
        .expect("heavy validation");
    assert!(
        heavy.measured_mean_latency > light.measured_mean_latency,
        "measured: heavier load, higher latency"
    );
    assert!(
        heavy.surrogate_latency > light.surrogate_latency,
        "surrogate: heavier load, higher latency"
    );
    // Magnitude: surrogate within 3x of measured at light load.
    let ratio = light.surrogate_latency as f64 / light.measured_mean_latency;
    assert!(
        (0.33..3.0).contains(&ratio),
        "light-load surrogate off by >3x: surrogate={} measured={}",
        light.surrogate_latency,
        light.measured_mean_latency
    );
}

#[test]
fn cache_artifact_hit_rates_monotone() {
    let Some((_rt, arts)) = load() else { return };
    let mut hist = [0f32; scalesim::runtime::artifacts::CACHE_D];
    for (i, h) in hist.iter_mut().enumerate() {
        *h = 100.0 / (i + 1) as f32;
    }
    let mut sizes = [0f32; scalesim::runtime::artifacts::CACHE_S];
    for (i, s) in sizes.iter_mut().enumerate() {
        *s = (1u64 << i) as f32;
    }
    let rates = arts.cache.hit_rates(&hist, &sizes).unwrap();
    for w in rates.windows(2) {
        assert!(w[1] >= w[0] - 1e-5, "monotone in size: {rates:?}");
    }
    assert!(rates.iter().all(|r| (0.0..=1.001).contains(r)));
}
