//! Facade-level tests (ISSUE 2): the `Sim` session API must reproduce the
//! pre-facade direct calls byte-for-byte, and the scenario registry must
//! be reachable from the CLI.
//!
//! The serial reference (`Model::run_serial`) is still public — it *is*
//! the pre-facade direct call — so each test builds the same scenario
//! model twice, runs one instance directly, one through `Sim`, and
//! compares fingerprints.

use scalesim::engine::{Engine, RunOpts, SchedMode, Sim};
use scalesim::scenario;
use scalesim::sched::PartitionStrategy;
use scalesim::sync::SyncMethod;
use scalesim::util::config::Config;

fn config(pairs: &[(&str, &str)]) -> Config {
    let mut c = Config::new();
    for &(k, v) in pairs {
        c.set(k, v);
    }
    c
}

#[test]
fn sim_reproduces_direct_serial_on_pipeline() {
    let cfg = config(&[("stages", "6"), ("messages", "40")]);
    // Pre-facade direct call: build the scenario's model and drive the
    // serial reference engine by hand.
    let (mut direct, stop) = scenario::find("pipeline").unwrap().build(&cfg).unwrap();
    let reference = direct.run_serial(RunOpts::with_stop(stop).fingerprinted());
    assert!(reference.fingerprint != 0);

    // The facade, across engines, workers, and scheduling modes, must
    // produce the identical fingerprint.
    for engine in [Engine::Serial, Engine::Partitioned, Engine::Ladder] {
        for workers in [1usize, 2, 3] {
            for sched in [SchedMode::FullScan, SchedMode::ActiveList] {
                let report = Sim::scenario("pipeline", &cfg)
                    .unwrap()
                    .workers(workers)
                    .sched(sched)
                    .fingerprinted()
                    .engine(engine)
                    .run()
                    .unwrap();
                assert_eq!(
                    report.fingerprint(),
                    reference.fingerprint,
                    "engine={} workers={workers} sched={}",
                    report.engine,
                    sched.name()
                );
                assert_eq!(report.stats.cycles, reference.cycles);
            }
        }
    }
}

#[test]
fn sim_reproduces_direct_serial_on_cpu_system() {
    let cfg = config(&[
        ("cores", "2"),
        ("txns", "8"),
        ("max-instrs", "20000"),
        ("max-cycles", "200000"),
    ]);
    let (mut direct, stop) = scenario::find("cpu-system").unwrap().build(&cfg).unwrap();
    let reference = direct.run_serial(RunOpts::with_stop(stop).fingerprinted());
    assert_eq!(reference.counters.get("cores_done"), 2);

    for (workers, strategy) in [
        (2usize, PartitionStrategy::Contiguous),
        (3, PartitionStrategy::CostBalanced),
    ] {
        let report = Sim::scenario("cpu-system", &cfg)
            .unwrap()
            .workers(workers)
            .strategy(strategy)
            .sync(SyncMethod::CommonAtomic)
            .fingerprinted()
            .engine(Engine::Ladder)
            .run()
            .unwrap();
        assert_eq!(
            report.fingerprint(),
            reference.fingerprint,
            "workers={workers} strategy={}",
            strategy.name()
        );
        assert_eq!(report.stats.cycles, reference.cycles);
        // The alias resolves to the canonical registry name.
        assert_eq!(report.scenario.as_deref(), Some("cpu-light"));
    }
}

#[test]
fn list_scenarios_cli_smoke() {
    let out = std::process::Command::new(env!("CARGO_BIN_EXE_scalesim"))
        .args(["run", "--list-scenarios"])
        .output()
        .expect("spawn scalesim");
    assert!(out.status.success(), "stderr: {}", String::from_utf8_lossy(&out.stderr));
    let stdout = String::from_utf8_lossy(&out.stdout);
    for name in [
        "pipeline", "cpu-light", "cpu-ooo", "fat-tree", "mesh", "ring", "torus", "tree",
    ] {
        assert!(stdout.contains(name), "{name} missing from:\n{stdout}");
    }
}

#[test]
fn run_scenario_cli_smoke() {
    let out = std::process::Command::new(env!("CARGO_BIN_EXE_scalesim"))
        .args([
            "run",
            "--scenario",
            "pipeline",
            "--set",
            "stages=4,messages=10",
            "--workers",
            "2",
            "--fingerprint",
        ])
        .output()
        .expect("spawn scalesim");
    assert!(out.status.success(), "stderr: {}", String::from_utf8_lossy(&out.stderr));
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert!(stdout.contains("ladder"), "engine line missing:\n{stdout}");
    assert!(stdout.contains("fingerprint"), "fingerprint missing:\n{stdout}");
}

#[test]
fn unknown_scenario_is_a_clean_error() {
    let err = Sim::scenario("nope", &Config::new()).err().unwrap();
    assert!(err.contains("unknown scenario"), "{err}");
    assert!(err.contains("pipeline"), "suggests the registry: {err}");
}
