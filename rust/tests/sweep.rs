//! Sweep subsystem: end-to-end coverage of the DSE driver.
//!
//! Pins the acceptance properties: a multi-scenario grid runs in
//! parallel and writes exactly one JSONL row per cell; a killed sweep
//! resumed with the same spec reruns only the missing cells; frontier
//! pruning is deterministic on a fixed cost table and provably prunes a
//! dominated cell; a failing cell is contained as an `"error"` row.

use std::collections::BTreeSet;
use std::path::PathBuf;

use scalesim::sweep::{plan, run_sweep, summarize, Cell, SweepOpts, SweepSpec};

/// Unique-per-test results path (the suite runs tests concurrently).
fn out_path(tag: &str) -> PathBuf {
    std::env::temp_dir().join(format!("scalesim_sweep_{}_{}.jsonl", tag, std::process::id()))
}

/// The acceptance grid: 2 scenarios × 2 packet counts × 2 worker counts
/// × 2 sched modes = 16 cells, small enough to run everywhere.
fn acceptance_spec() -> SweepSpec {
    let mut spec = SweepSpec::new(&["ring", "torus"]).unwrap();
    spec.grid_from("packets=2,4").unwrap();
    spec.workers_from("1,2").unwrap();
    spec.scheds_from("full,active").unwrap();
    spec
}

fn read_rows(path: &std::path::Path) -> Vec<String> {
    std::fs::read_to_string(path)
        .unwrap_or_default()
        .lines()
        .map(str::to_string)
        .collect()
}

fn cell_keys(rows: &[String]) -> BTreeSet<String> {
    rows.iter()
        .filter_map(|r| {
            let at = r.find("\"cell\": \"")? + "\"cell\": \"".len();
            Some(r[at..at + r[at..].find('"')?].to_string())
        })
        .collect()
}

#[test]
fn sweep_writes_one_row_per_cell_and_resumes() {
    let out = out_path("resume");
    let _ = std::fs::remove_file(&out);
    let spec = acceptance_spec();
    let opts = SweepOpts {
        out: out.clone(),
        jobs: 2,
        cores: 2,
        ..SweepOpts::default()
    };

    let outcome = run_sweep(&spec, &opts).unwrap();
    assert_eq!(outcome.planned, 16);
    assert_eq!(outcome.ran, 16);
    assert_eq!(outcome.resumed, 0);
    assert_eq!(outcome.errors, 0);
    let rows = read_rows(&out);
    assert_eq!(rows.len(), 16, "one JSONL row per cell");
    let planned: BTreeSet<String> = plan(&spec).unwrap().into_iter().map(|c| c.key).collect();
    assert_eq!(cell_keys(&rows), planned, "rows carry exactly the planned keys");
    for row in &rows {
        assert!(row.contains("\"status\": \"ok\""), "{row}");
        assert!(row.contains("\"fingerprint\": \"0x"), "{row}");
        assert!(row.contains("\"report\": {"), "{row}");
    }

    // Kill-mid-sweep model: truncate to half the rows, plus one garbage
    // tail line (a row the "kill" cut mid-write) that must be ignored.
    let half: String = rows[..8].join("\n") + "\n" + &rows[8][..rows[8].len() / 2];
    std::fs::write(&out, half).unwrap();
    let outcome = run_sweep(&spec, &opts).unwrap();
    assert_eq!(outcome.resumed, 8, "completed cells are skipped");
    assert_eq!(outcome.ran, 8, "only the missing cells rerun");
    let rows = read_rows(&out);
    // 8 intact + 1 truncated + 8 rerun lines; the key set is complete
    // again, with the truncated cell's key present via its rerun row.
    assert_eq!(rows.len(), 17);
    assert_eq!(cell_keys(&rows), planned);

    // A third run with everything present reruns nothing.
    let outcome = run_sweep(&spec, &opts).unwrap();
    assert_eq!(outcome.resumed, 16);
    assert_eq!(outcome.ran, 0);
    let _ = std::fs::remove_file(&out);
}

#[test]
fn sweep_results_summarize_and_feed_bench() {
    let out = out_path("summarize");
    let _ = std::fs::remove_file(&out);
    let mut spec = SweepSpec::new(&["ring"]).unwrap();
    spec.grid_from("packets=2;nodes=4").unwrap();
    spec.workers_from("1,2").unwrap();
    let opts = SweepOpts {
        out: out.clone(),
        jobs: 1,
        cores: 2,
        ..SweepOpts::default()
    };
    run_sweep(&spec, &opts).unwrap();

    let sum = summarize(&out).unwrap();
    assert_eq!(sum.rows, 2);
    assert_eq!(sum.ok, 2);
    assert_eq!(sum.errors + sum.dominated + sum.malformed, 0);
    let ring = &sum.scenarios["ring"];
    assert_eq!(ring.ok, 2);
    let best = ring.best.as_ref().expect("a best cell");
    assert!(best.cycles_per_sec > 0.0);
    assert!(best.fingerprint.starts_with("0x"));

    // The bench bridge rebuilds BenchRows from the embedded reports.
    let bench = scalesim::sweep::bench_from_results(&out, None).unwrap();
    assert_eq!(bench.model, "sweep");
    assert_eq!(bench.scenario, "ring");
    assert_eq!(bench.rows.len(), 2);
    assert!(bench.fingerprints_agree(), "serial and ladder rows agree");
    let json = bench.to_json();
    assert!(json.contains("\"model\": \"sweep\""), "{json}");
    let _ = std::fs::remove_file(&out);
}

#[test]
fn frontier_prunes_a_dominated_lane_deterministically() {
    let out = out_path("frontier");
    let _ = std::fs::remove_file(&out);
    // One family (ring, packets=2), two lanes (sched full vs active),
    // two worker coordinates each.
    let mut spec = SweepSpec::new(&["ring"]).unwrap();
    spec.grid_from("packets=2").unwrap();
    spec.workers_from("1,2").unwrap();
    spec.scheds_from("full,active").unwrap();

    // Fixed cost table: active-list always scores 10x full-scan. With
    // --jobs 1 the claim order is the planner order — (w=1,full),
    // (w=1,active), (w=2,full), (w=2,active). When (w=2,full) is
    // claimed, the full-scan lane's only completed coordinate (w=1) is
    // strictly beaten by active-list, so it is dominated and pruned.
    // Deterministic because jobs=1 fixes the order and the score is a
    // pure function of the cell.
    fn fixed_score(cell: &Cell, _r: &scalesim::engine::RunReport) -> f64 {
        match cell.sched.name() {
            "active-list" => 1000.0,
            _ => 100.0,
        }
    }
    let opts = SweepOpts {
        out: out.clone(),
        jobs: 1,
        cores: 1,
        frontier: true,
        score: Some(fixed_score),
        ..SweepOpts::default()
    };
    let outcome = run_sweep(&spec, &opts).unwrap();
    assert_eq!(outcome.planned, 4);
    assert!(
        outcome.dominated >= 1,
        "the losing lane's later cell must be pruned: {outcome:?}"
    );
    let rows = read_rows(&out);
    let pruned: Vec<&String> = rows
        .iter()
        .filter(|r| r.contains("\"status\": \"skipped:dominated\""))
        .collect();
    assert_eq!(pruned.len(), outcome.dominated);
    for row in &pruned {
        assert!(row.contains("sched=full-scan"), "only the slow lane: {row}");
        assert!(row.contains("\"dominated_by\": \""), "{row}");
    }
    // Determinism: a fresh run of the same spec prunes the same cells.
    let out2 = out_path("frontier2");
    let _ = std::fs::remove_file(&out2);
    let opts2 = SweepOpts {
        out: out2.clone(),
        ..opts
    };
    run_sweep(&spec, &opts2).unwrap();
    let again: Vec<String> = read_rows(&out2)
        .into_iter()
        .filter(|r| r.contains("skipped:dominated"))
        .collect();
    assert_eq!(
        cell_keys(&again),
        cell_keys(&pruned.into_iter().cloned().collect::<Vec<_>>()),
        "pruning is deterministic"
    );
    let _ = std::fs::remove_file(&out);
    let _ = std::fs::remove_file(&out2);
}

#[test]
fn failing_cells_are_contained_as_error_rows() {
    let out = out_path("errors");
    let _ = std::fs::remove_file(&out);
    // Grid over run length: the cycles=3 cells finish before the
    // injected cycle-5 panic arms; the cycles=50 cells hit it.
    let mut spec = SweepSpec::new(&["pipeline"]).unwrap();
    spec.grid_from("stages=4;messages=50;cycles=3,50").unwrap();
    spec.workers_from("2").unwrap();
    let opts = SweepOpts {
        out: out.clone(),
        jobs: 1,
        cores: 2,
        inject: Some("panic@5:1".to_string()),
        ..SweepOpts::default()
    };
    let outcome = run_sweep(&spec, &opts).unwrap();
    assert_eq!(outcome.planned, 2);
    assert_eq!(outcome.ran, 2, "the sweep finishes despite the failure");
    assert_eq!(outcome.errors, 1);
    let rows = read_rows(&out);
    let errors: Vec<&String> = rows
        .iter()
        .filter(|r| r.contains("\"status\": \"error\""))
        .collect();
    assert_eq!(errors.len(), 1);
    assert!(errors[0].contains("cycles=50"), "{}", errors[0]);
    assert!(errors[0].contains("SimError"), "structured error: {}", errors[0]);
    assert!(
        rows.iter().any(|r| r.contains("\"status\": \"ok\"") && r.contains("cycles=3")),
        "the short cells still complete"
    );
    let _ = std::fs::remove_file(&out);
}

#[test]
fn worker_cap_keeps_fingerprints_and_budgets_nested_parallelism() {
    // Two cells on 2 cores with a workers=2 axis: at --jobs 2 each cell
    // is capped to one ladder worker (2 jobs × 1 worker = 2 cores); at
    // --jobs 1 the same cells run uncapped at 2 workers. Per-cell
    // fingerprints must be identical — the cap changes engine topology,
    // never simulation semantics.
    let out_capped = out_path("cap");
    let out_free = out_path("capfree");
    let _ = std::fs::remove_file(&out_capped);
    let _ = std::fs::remove_file(&out_free);
    let mut spec = SweepSpec::new(&["ring"]).unwrap();
    spec.grid_from("packets=2,4").unwrap();
    spec.workers_from("2").unwrap();
    let capped = run_sweep(
        &spec,
        &SweepOpts {
            out: out_capped.clone(),
            jobs: 2,
            cores: 2,
            ..SweepOpts::default()
        },
    )
    .unwrap();
    assert_eq!(capped.jobs, 2);
    assert_eq!(capped.worker_cap, 1);
    let free = run_sweep(
        &spec,
        &SweepOpts {
            out: out_free.clone(),
            jobs: 1,
            cores: 2,
            ..SweepOpts::default()
        },
    )
    .unwrap();
    assert_eq!(free.worker_cap, 2);
    // Row order differs under parallel appends; compare key -> fp maps.
    let fps = |p: &std::path::Path| {
        read_rows(p)
            .into_iter()
            .map(|r| {
                let key = {
                    let at = r.find("\"cell\": \"").unwrap() + "\"cell\": \"".len();
                    r[at..at + r[at..].find('"').unwrap()].to_string()
                };
                let at = r.find("\"fingerprint\": \"").unwrap() + "\"fingerprint\": \"".len();
                (key, r[at..at + 18].to_string())
            })
            .collect::<std::collections::BTreeMap<_, _>>()
    };
    assert_eq!(fps(&out_capped), fps(&out_free), "the cap never changes semantics");
    let _ = std::fs::remove_file(&out_capped);
    let _ = std::fs::remove_file(&out_free);
}

// ---------------------------------------------------------------------
// CLI surface
// ---------------------------------------------------------------------

fn scalesim() -> std::process::Command {
    std::process::Command::new(env!("CARGO_BIN_EXE_scalesim"))
}

#[test]
fn sweep_cli_dry_run_lists_stable_keys() {
    let out = scalesim()
        .args([
            "sweep",
            "--scenario",
            "ring,torus",
            "--set",
            "packets=2,4",
            "--workers",
            "1,2",
            "--dry-run",
        ])
        .output()
        .expect("spawn scalesim");
    assert!(
        out.status.success(),
        "stderr: {}",
        String::from_utf8_lossy(&out.stderr)
    );
    let stdout = String::from_utf8_lossy(&out.stdout);
    let keys: Vec<&str> = stdout.lines().filter(|l| l.starts_with("scenario=")).collect();
    assert_eq!(keys.len(), 8, "{stdout}");
    assert!(
        keys[0].contains("scenario=ring") && keys[0].contains("workers=1"),
        "{stdout}"
    );
    assert!(stdout.contains("# sweep: planned=8"), "{stdout}");
}

#[test]
fn sweep_cli_runs_resumes_and_summarizes() {
    let out_file = out_path("cli");
    let _ = std::fs::remove_file(&out_file);
    let run = || {
        scalesim()
            .args([
                "sweep",
                "--scenario",
                "ring",
                "--set",
                "packets=2;nodes=4",
                "--workers",
                "1,2",
                "--jobs",
                "1",
                "--out",
            ])
            .arg(&out_file)
            .output()
            .expect("spawn scalesim")
    };
    let first = run();
    assert!(
        first.status.success(),
        "stderr: {}",
        String::from_utf8_lossy(&first.stderr)
    );
    let stdout = String::from_utf8_lossy(&first.stdout);
    assert!(
        stdout.contains("ran=2 resumed=0"),
        "summary line: {stdout}"
    );
    // Rerun with the same spec: everything resumes.
    let second = run();
    let stdout = String::from_utf8_lossy(&second.stdout);
    assert!(
        stdout.contains("ran=0 resumed=2"),
        "summary line: {stdout}"
    );
    // Summarize mode prints the greppable totals line.
    let sum = scalesim()
        .args(["sweep", "--summarize", out_file.to_str().unwrap()])
        .output()
        .expect("spawn scalesim");
    assert!(
        sum.status.success(),
        "stderr: {}",
        String::from_utf8_lossy(&sum.stderr)
    );
    let stdout = String::from_utf8_lossy(&sum.stdout);
    assert!(stdout.contains("# summarize: rows=2 ok=2"), "{stdout}");
    let _ = std::fs::remove_file(&out_file);
}

#[test]
fn unknown_set_keys_fail_fast_with_a_suggestion() {
    // `run` rejects a typo'd key before building anything.
    let out = scalesim()
        .args(["run", "--scenario", "ring", "--set", "packet=2"])
        .output()
        .expect("spawn scalesim");
    assert!(!out.status.success());
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(stderr.contains("did you mean \"packets\"?"), "{stderr}");

    // `sweep` does the same, and names the scenario that lacks the key
    // on a multi-scenario grid.
    let out = scalesim()
        .args(["sweep", "--scenario", "ring,torus", "--set", "nodes=4,8", "--dry-run"])
        .output()
        .expect("spawn scalesim");
    assert!(!out.status.success());
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(stderr.contains("torus"), "{stderr}");
}

#[test]
fn list_scenarios_verbose_documents_the_keys() {
    let terse = scalesim()
        .args(["run", "--list-scenarios"])
        .output()
        .expect("spawn scalesim");
    assert!(terse.status.success());
    let terse = String::from_utf8_lossy(&terse.stdout).to_string();
    let verbose = scalesim()
        .args(["run", "--list-scenarios", "--verbose"])
        .output()
        .expect("spawn scalesim");
    assert!(verbose.status.success());
    let verbose = String::from_utf8_lossy(&verbose.stdout).to_string();
    // "link-capacity" only ever appears as a declared key, never in a
    // scenario summary line.
    assert!(!terse.contains("link-capacity"), "terse mode omits keys:\n{terse}");
    assert!(terse.contains("--verbose"), "terse mode hints at --verbose:\n{terse}");
    assert!(verbose.contains("link-capacity"), "verbose lists keys:\n{verbose}");
    assert!(verbose.contains("repartition"), "session keys too:\n{verbose}");
    assert!(verbose.lines().count() > terse.lines().count());
}
