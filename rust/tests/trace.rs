//! The compiled-in tracing layer (DESIGN.md §2h), end to end:
//!
//! 1. Observer contract: tracing must not perturb the simulation.
//!    Every (scenario, workers, sched) cell must produce bit-identical
//!    fingerprints and cycle counts with tracing on and off.
//! 2. Export shape: a traced 2-worker ladder run on the tree fabric
//!    writes Chrome `trace_event` JSON that parses back with one named
//!    track per worker plus the engine track, and carries at least one
//!    barrier span and one fast-forward jump instant (the acceptance
//!    criterion).
//! 3. Bounded buffers: a tiny per-track ring must finish (never block
//!    the hot loop), report `trace.dropped > 0`, and still export a
//!    valid document.
//! 4. Emitter hygiene: JSON emitters escape `"`/`\` in names and never
//!    print non-finite floats (degenerate zero-cycle runs included).
//!
//! The parser here is a deliberately small recursive-descent JSON
//! reader — the crate is dependency-free, and the exporter's output is
//! machine-written with known shape; the point is that a *real* parser
//! accepts it, not just substring checks.

use std::collections::BTreeMap;
use std::path::PathBuf;

use scalesim::engine::{Engine, SchedMode, Sim};
use scalesim::util::config::Config;

// ---------------------------------------------------------------------
// Minimal JSON value + parser (tests only)
// ---------------------------------------------------------------------

#[derive(Debug, Clone, PartialEq)]
enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s.as_str()),
            _ => None,
        }
    }

    fn as_num(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v.as_slice()),
            _ => None,
        }
    }
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn parse(text: &'a str) -> Result<Json, String> {
        let mut p = Parser {
            b: text.as_bytes(),
            i: 0,
        };
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            return Err(format!("trailing garbage at byte {}", p.i));
        }
        Ok(v)
    }

    fn ws(&mut self) {
        while self.i < self.b.len() && self.b[self.i].is_ascii_whitespace() {
            self.i += 1;
        }
    }

    fn eat(&mut self, c: u8) -> Result<(), String> {
        self.ws();
        if self.i < self.b.len() && self.b[self.i] == c {
            self.i += 1;
            Ok(())
        } else {
            Err(format!("expected {:?} at byte {}", c as char, self.i))
        }
    }

    fn peek(&mut self) -> Option<u8> {
        self.ws();
        self.b.get(self.i).copied()
    }

    fn value(&mut self) -> Result<Json, String> {
        match self.peek().ok_or("unexpected end of input")? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'n' => self.lit("null", Json::Null),
            _ => self.number(),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, String> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.i))
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.eat(b':')?;
            let v = self.value()?;
            m.insert(k, v);
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(format!("expected ',' or '}}' at byte {}", self.i)),
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.eat(b'[')?;
        let mut v = Vec::new();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            v.push(self.value()?);
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(format!("expected ',' or ']' at byte {}", self.i)),
            }
        }
    }

    fn string(&mut self) -> Result<String, String> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            let c = *self
                .b
                .get(self.i)
                .ok_or("unterminated string")?;
            self.i += 1;
            match c {
                b'"' => return Ok(s),
                b'\\' => {
                    let e = *self.b.get(self.i).ok_or("bad escape")?;
                    self.i += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'n' => s.push('\n'),
                        b'r' => s.push('\r'),
                        b't' => s.push('\t'),
                        b'u' => {
                            let hex = self
                                .b
                                .get(self.i..self.i + 4)
                                .ok_or("bad \\u escape")?;
                            let code = u32::from_str_radix(
                                std::str::from_utf8(hex).map_err(|e| e.to_string())?,
                                16,
                            )
                            .map_err(|e| e.to_string())?;
                            self.i += 4;
                            s.push(char::from_u32(code).ok_or("bad \\u code point")?);
                        }
                        other => return Err(format!("bad escape \\{}", other as char)),
                    }
                }
                c if c < 0x80 => s.push(c as char),
                _ => {
                    // Multi-byte UTF-8: re-decode from the byte stream.
                    let start = self.i - 1;
                    let len = utf8_len(c);
                    let chunk = self
                        .b
                        .get(start..start + len)
                        .ok_or("truncated UTF-8")?;
                    s.push_str(std::str::from_utf8(chunk).map_err(|e| e.to_string())?);
                    self.i = start + len;
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        self.ws();
        let start = self.i;
        while self.i < self.b.len()
            && matches!(self.b[self.i], b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
        {
            self.i += 1;
        }
        std::str::from_utf8(&self.b[start..self.i])
            .ok()
            .and_then(|t| t.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| format!("bad number at byte {start}"))
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        _ => 4,
    }
}

// ---------------------------------------------------------------------
// Fixtures
// ---------------------------------------------------------------------

fn cfg(pairs: &[(&str, &str)]) -> Config {
    let mut c = Config::new();
    for (k, v) in pairs {
        c.set(k, v);
    }
    c
}

fn trace_path(tag: &str) -> PathBuf {
    std::env::temp_dir().join(format!("scalesim_trace_{}_{}.json", tag, std::process::id()))
}

/// Apply one engine-topology cell to a session.
fn topo(sim: Sim, workers: usize, sched: SchedMode) -> Sim {
    let engine = if workers <= 1 {
        Engine::Serial
    } else {
        Engine::Ladder
    };
    sim.workers(workers).engine(engine).sched(sched).fingerprinted()
}

/// The sparse tree fabric that drains early: exercises ff jumps,
/// sleep/wake edges, and barriers all at once.
fn tree_pairs() -> Vec<(&'static str, &'static str)> {
    vec![
        ("fanout", "4"),
        ("depth", "3"),
        ("packets", "2"),
        ("cycles", "600"),
    ]
}

fn pipeline_pairs() -> Vec<(&'static str, &'static str)> {
    vec![("stages", "6"), ("messages", "40"), ("cycles", "300")]
}

// ---------------------------------------------------------------------
// 1. Observer contract: tracing never changes the simulation
// ---------------------------------------------------------------------

fn assert_trace_parity(scenario: &str, pairs: &[(&str, &str)]) {
    let c = cfg(pairs);
    for workers in [1usize, 2, 4] {
        for sched in [SchedMode::FullScan, SchedMode::ActiveList] {
            let cell = format!("{scenario}: workers={workers} sched={}", sched.name());
            let plain = topo(Sim::scenario(scenario, &c).unwrap(), workers, sched)
                .run()
                .unwrap_or_else(|e| panic!("{cell} untraced: {e}"));
            let path = trace_path(&format!("parity_{scenario}_{workers}_{}", sched.name()));
            let traced = topo(Sim::scenario(scenario, &c).unwrap(), workers, sched)
                .trace(&path)
                .run()
                .unwrap_or_else(|e| panic!("{cell} traced: {e}"));
            assert_ne!(plain.fingerprint(), 0, "{cell}: no fingerprint");
            assert_eq!(
                traced.fingerprint(),
                plain.fingerprint(),
                "{cell}: tracing changed the fingerprint"
            );
            assert_eq!(
                traced.stats.cycles, plain.stats.cycles,
                "{cell}: tracing changed the cycle count"
            );
            assert_eq!(
                plain.stats.counters.get("trace.events"),
                0,
                "{cell}: untraced run must not count trace events"
            );
            assert!(
                traced.stats.counters.get("trace.events") > 0,
                "{cell}: traced run recorded nothing"
            );
            let _ = std::fs::remove_file(&path);
        }
    }
}

#[test]
fn tracing_on_off_parity_pipeline() {
    assert_trace_parity("pipeline", &pipeline_pairs());
}

#[test]
fn tracing_on_off_parity_tree() {
    assert_trace_parity("tree", &tree_pairs());
}

// ---------------------------------------------------------------------
// 2. Export shape (the acceptance run): 2-worker tree, parsed back
// ---------------------------------------------------------------------

#[test]
fn ladder_trace_exports_parseable_chrome_json() {
    let path = trace_path("ladder_tree");
    let report = topo(
        Sim::scenario("tree", &cfg(&tree_pairs())).unwrap(),
        2,
        SchedMode::ActiveList,
    )
    .trace(&path)
    .run()
    .expect("traced tree run");
    assert!(report.stats.ff_jumps > 0, "tree run must fast-forward");
    assert!(report.stats.counters.get("trace.events") > 0);

    let text = std::fs::read_to_string(&path).expect("trace file exists");
    let doc = Parser::parse(&text).expect("trace file is valid JSON");

    // otherData carries the run identity and the counter totals.
    let other = doc.get("otherData").expect("otherData");
    assert_eq!(other.get("scenario").and_then(Json::as_str), Some("tree"));
    assert_eq!(other.get("engine").and_then(Json::as_str), Some("ladder"));
    assert_eq!(other.get("workers").and_then(Json::as_str), Some("2"));
    assert_eq!(
        other.get("trace_events").and_then(Json::as_num),
        Some(report.stats.counters.get("trace.events") as f64)
    );

    let events = doc
        .get("traceEvents")
        .and_then(Json::as_arr)
        .expect("traceEvents array");

    // One named track per worker plus the engine track.
    let mut tracks: BTreeMap<u64, String> = BTreeMap::new();
    for ev in events {
        if ev.get("name").and_then(Json::as_str) == Some("thread_name") {
            let tid = ev.get("tid").and_then(Json::as_num).expect("tid") as u64;
            let label = ev
                .get("args")
                .and_then(|a| a.get("name"))
                .and_then(Json::as_str)
                .expect("thread label");
            tracks.insert(tid, label.to_string());
        }
    }
    assert_eq!(tracks.get(&0).map(String::as_str), Some("engine"));
    assert_eq!(tracks.get(&1).map(String::as_str), Some("cluster 0"));
    assert_eq!(tracks.get(&2).map(String::as_str), Some("cluster 1"));

    // Every non-metadata event is well-formed and lands on a known track.
    let mut barriers = 0u64;
    let mut ff_jumps = 0u64;
    let mut per_worker_spans = 0u64;
    for ev in events {
        let ph = ev.get("ph").and_then(Json::as_str).expect("ph");
        if ph == "M" {
            continue;
        }
        let tid = ev.get("tid").and_then(Json::as_num).expect("tid") as u64;
        assert!(tracks.contains_key(&tid), "event on unnamed track {tid}");
        assert!(ev.get("ts").and_then(Json::as_num).is_some(), "ts missing");
        let name = ev.get("name").and_then(Json::as_str).expect("name");
        let cycle = ev
            .get("args")
            .and_then(|a| a.get("cycle"))
            .and_then(Json::as_num)
            .expect("args.cycle");
        assert!(cycle >= 0.0);
        match ph {
            "X" => {
                assert!(ev.get("dur").and_then(Json::as_num).is_some(), "dur");
                if name == "barrier" {
                    assert_eq!(tid, 0, "barriers live on the engine track");
                    barriers += 1;
                }
                if tid > 0 && (name == "work" || name == "transfer") {
                    per_worker_spans += 1;
                }
            }
            "i" => {
                if name == "ff-jump" {
                    assert_eq!(tid, 0, "ff jumps live on the engine track");
                    ff_jumps += 1;
                }
            }
            other => panic!("unexpected phase {other:?}"),
        }
    }
    assert!(barriers >= 1, "expected at least one barrier span");
    assert!(ff_jumps >= 1, "expected at least one ff-jump instant");
    assert!(
        per_worker_spans >= 2,
        "expected work/transfer spans on worker tracks"
    );
    let _ = std::fs::remove_file(&path);
}

#[test]
fn serial_trace_exports_single_track() {
    let path = trace_path("serial_pipeline");
    let report = topo(
        Sim::scenario("pipeline", &cfg(&pipeline_pairs())).unwrap(),
        1,
        SchedMode::FullScan,
    )
    .trace(&path)
    .run()
    .expect("traced serial run");
    assert!(report.stats.counters.get("trace.events") > 0);
    let doc = Parser::parse(&std::fs::read_to_string(&path).unwrap()).expect("valid JSON");
    let events = doc.get("traceEvents").and_then(Json::as_arr).unwrap();
    let labels: Vec<&str> = events
        .iter()
        .filter(|e| e.get("name").and_then(Json::as_str) == Some("thread_name"))
        .filter_map(|e| e.get("args")?.get("name")?.as_str())
        .collect();
    assert_eq!(labels, vec!["serial"], "one track, labeled serial");
    assert!(events
        .iter()
        .any(|e| e.get("name").and_then(Json::as_str) == Some("work")));
    let _ = std::fs::remove_file(&path);
}

// ---------------------------------------------------------------------
// 3. Bounded buffers: tiny rings drop, never hang, still export
// ---------------------------------------------------------------------

#[test]
fn tiny_ring_drops_without_hanging() {
    let path = trace_path("tiny_ring");
    let report = topo(
        Sim::scenario("tree", &cfg(&tree_pairs())).unwrap(),
        2,
        SchedMode::FullScan,
    )
    .trace(&path)
    .trace_buf(8)
    .run()
    .expect("tiny-ring run finishes");
    let dropped = report.stats.counters.get("trace.dropped");
    assert!(dropped > 0, "8-event rings must overflow on this run");
    assert!(report.to_json().contains("\"trace_dropped\": "));

    // The export is still a valid document and reports the drops.
    let doc = Parser::parse(&std::fs::read_to_string(&path).unwrap()).expect("valid JSON");
    assert_eq!(
        doc.get("otherData")
            .and_then(|o| o.get("trace_dropped"))
            .and_then(Json::as_num),
        Some(dropped as f64)
    );
    // Kept events respect the per-track cap.
    let events = doc.get("traceEvents").and_then(Json::as_arr).unwrap();
    let mut per_track: BTreeMap<u64, u64> = BTreeMap::new();
    for ev in events {
        if ev.get("ph").and_then(Json::as_str) == Some("M") {
            continue;
        }
        let tid = ev.get("tid").and_then(Json::as_num).unwrap() as u64;
        *per_track.entry(tid).or_insert(0) += 1;
    }
    for (tid, n) in per_track {
        assert!(n <= 8, "track {tid} kept {n} events, cap is 8");
    }
    let _ = std::fs::remove_file(&path);
}

// ---------------------------------------------------------------------
// 4. Emitter hygiene: escaping and finite floats
// ---------------------------------------------------------------------

#[test]
fn report_json_escapes_weird_scenario_names() {
    let mut report = topo(
        Sim::scenario("pipeline", &cfg(&pipeline_pairs())).unwrap(),
        1,
        SchedMode::FullScan,
    )
    .run()
    .expect("pipeline run");
    // Scenario names are registry-controlled today, but the emitter must
    // not rely on that: a quote or backslash in the echoed name has to
    // round-trip through a real parser.
    report.scenario = Some("we\"ird\\name".to_string());
    let json = report.to_json();
    let doc = Parser::parse(&json).expect("report row with escapes parses");
    assert_eq!(
        doc.get("scenario").and_then(Json::as_str),
        Some("we\"ird\\name")
    );
}

#[test]
fn zero_cycle_run_emits_finite_parseable_json() {
    let report = topo(
        Sim::scenario("pipeline", &cfg(&pipeline_pairs())).unwrap(),
        1,
        SchedMode::FullScan,
    )
    .cycles(0)
    .run()
    .expect("zero-cycle run");
    assert_eq!(report.stats.cycles, 0);
    let json = report.to_json();
    assert!(!json.contains("inf"), "non-finite rate leaked: {json}");
    assert!(!json.contains("NaN"), "non-finite rate leaked: {json}");
    let doc = Parser::parse(&json).expect("zero-cycle report parses");
    assert!(doc
        .get("cycles_per_sec")
        .and_then(Json::as_num)
        .is_some_and(f64::is_finite));
    assert!(doc
        .get("active_ratio")
        .and_then(Json::as_num)
        .is_some_and(f64::is_finite));
}
