//! Sleep/wake stress tests: the lost-wakeup hazard class.
//!
//! The dangerous schedule is: a sink goes quiescent and parks; a message
//! for it is still in flight (staged upstream, or queued with a
//! multi-cycle port delay still running); the delivery must re-arm the
//! sink, and nothing may be stranded. The protocol's defense is twofold —
//! a unit only parks when *all* of its input queues are empty (counting
//! not-yet-ready messages), and any later 0 → 1 delivery posts a wake —
//! and these tests drive both edges with port delays > 1, burst gaps,
//! multi-hop chains, and cross-cluster parallel runs.

//! The burst/relay/sink units and models live in `tests/common`.

mod common;

use common::{all_idle, burst_model, chain_model, BurstSource};
use scalesim::engine::{Ctx, Engine, Fnv, In, ModelBuilder, PortCfg, RunOpts, Sim, Transit, Unit};
use scalesim::stats::StatsMap;
use scalesim::sync::SyncMethod;

#[test]
fn delayed_delivery_rearms_parked_sink() {
    for delay in [2u64, 4, 7] {
        // Reference semantics: full scan.
        let mut reference = burst_model(delay);
        let r = reference.run_serial(RunOpts::with_stop(all_idle()).fingerprinted());
        assert_eq!(r.counters.get("sink.received"), 8, "delay={delay}");

        // Sleep/wake serial: same fingerprint, same deliveries, and the
        // run must still terminate via AllIdle (a stranded message or a
        // never-parked unit would push it to max_cycles).
        let mut active = burst_model(delay);
        let a = active.run_serial(
            RunOpts::with_stop(all_idle()).fingerprinted().active_list(),
        );
        assert_eq!(
            a.fingerprint, r.fingerprint,
            "delay={delay}: active-list diverged"
        );
        assert_eq!(a.counters.get("sink.received"), 8, "delay={delay}");
        assert_eq!(a.cycles, r.cycles, "delay={delay}: drain time must match");
        assert!(a.cycles < 200, "delay={delay}: AllIdle must fire: {}", a.cycles);
        // The sink slept through the gaps: far fewer ticks than 2 units
        // × cycles.
        assert!(
            a.unit_ticks() < r.unit_ticks(),
            "delay={delay}: no parking happened ({} vs {})",
            a.unit_ticks(),
            r.unit_ticks()
        );
    }
}

#[test]
fn wake_crosses_cluster_boundary() {
    for delay in [2u64, 5] {
        let serial_fp = {
            let mut m = burst_model(delay);
            m.run_serial(RunOpts::cycles(120).fingerprinted()).fingerprint
        };
        for method in SyncMethod::ALL {
            // src and snk on different clusters: the wake must travel
            // through the cross-cluster box, ordered by the phase barrier.
            let stats = Sim::from_model(burst_model(delay))
                .partition(vec![vec![0], vec![1]])
                .sync(method)
                .cycles(120)
                .fingerprinted()
                .active_list()
                .engine(Engine::Ladder)
                .run()
                .expect("ladder run")
                .stats;
            assert_eq!(
                stats.fingerprint,
                serial_fp,
                "delay={delay} method={}",
                method.name()
            );
        }
    }
}

#[test]
fn wake_propagates_along_chain() {
    for delay in [1u64, 3] {
        let mut reference = chain_model(delay);
        let r = reference.run_serial(RunOpts::with_stop(all_idle()).fingerprinted());
        assert_eq!(r.counters.get("sink.received"), 4, "delay={delay}");

        let mut active = chain_model(delay);
        let a = active.run_serial(
            RunOpts::with_stop(all_idle()).fingerprinted().active_list(),
        );
        assert_eq!(a.fingerprint, r.fingerprint, "delay={delay}");
        assert_eq!(a.cycles, r.cycles, "delay={delay}");

        // One cluster per unit in parallel: every hop is a cross-cluster
        // wake.
        let p = Sim::from_model(chain_model(delay))
            .partition(vec![vec![0], vec![1], vec![2]])
            .sync(SyncMethod::CommonAtomic)
            .stop(all_idle())
            .fingerprinted()
            .active_list()
            .engine(Engine::Ladder)
            .run()
            .expect("ladder run")
            .stats;
        assert_eq!(p.fingerprint, r.fingerprint, "delay={delay} parallel");
        assert_eq!(p.counters.get("sink.received"), 4, "delay={delay}");
    }
}

#[test]
fn simultaneous_wakes_from_two_senders_collapse() {
    // Two sources deliver into a parked sink in the same transfer phase
    // (same cycle, two ports): the drain pass must collapse the duplicate
    // wakes and the sink must receive everything exactly once.
    let build = || {
        let mut mb = ModelBuilder::new();
        let a = mb.reserve_unit("a");
        let b = mb.reserve_unit("b");
        let snk = mb.reserve_unit("snk");
        let (ta, ra) = mb.link::<Transit>(a, snk, PortCfg::new(2, 3));
        let (tb, rb) = mb.link::<Transit>(b, snk, PortCfg::new(2, 3));
        struct TwoPortSink {
            ins: [In<Transit>; 2],
            received: u64,
        }
        impl Unit for TwoPortSink {
            fn work(&mut self, ctx: &mut Ctx<'_>) {
                for &inp in &self.ins {
                    while let Some(_m) = inp.recv_msg(ctx) {
                        self.received += 1;
                    }
                }
            }
            fn state_hash(&self, h: &mut Fnv) {
                h.write_u64(self.received);
            }
            fn stats(&self, out: &mut StatsMap) {
                out.add("sink.received", self.received);
            }
        }
        mb.install(
            a,
            Box::new(BurstSource {
                out: ta,
                schedule: vec![10, 30],
                next: 0,
            }),
        );
        mb.install(
            b,
            Box::new(BurstSource {
                out: tb,
                schedule: vec![10, 30],
                next: 0,
            }),
        );
        mb.install(
            snk,
            Box::new(TwoPortSink {
                ins: [ra, rb],
                received: 0,
            }),
        );
        mb.build().unwrap()
    };
    let mut reference = build();
    let r = reference.run_serial(RunOpts::with_stop(all_idle()).fingerprinted());
    assert_eq!(r.counters.get("sink.received"), 4);

    let mut active = build();
    let a = active.run_serial(RunOpts::with_stop(all_idle()).fingerprinted().active_list());
    assert_eq!(a.fingerprint, r.fingerprint);
    assert_eq!(a.counters.get("sink.received"), 4);

    // Parallel: both senders on one cluster, sink on another, then one
    // cluster each.
    for part in [vec![vec![0, 1], vec![2]], vec![vec![0], vec![1], vec![2]]] {
        let p = Sim::from_model(build())
            .partition(part.clone())
            .sync(SyncMethod::CommonAtomic)
            .stop(all_idle())
            .fingerprinted()
            .active_list()
            .engine(Engine::Ladder)
            .run()
            .expect("ladder run")
            .stats;
        assert_eq!(p.fingerprint, r.fingerprint, "partition {part:?}");
    }
}
