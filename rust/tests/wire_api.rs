//! The typed wiring layer, end to end (ISSUE 4):
//!
//! 1. Payload roundtrips: every substrate message type encodes/decodes
//!    losslessly through the POD `Msg` scalar words.
//! 2. Builder validation: the four `BuildError` cases surface as typed
//!    errors implementing `Display` + `std::error::Error`.
//! 3. Construction parity: building the fat-tree and the CPU system
//!    through the **legacy raw tuple API** (`ModelBuilder::connect` +
//!    `from_raw` wrapping — this file is the one sanctioned user of that
//!    path outside `engine/`, exempted by name in the CI acceptance grep)
//!    produces bit-identical simulations to the typed production
//!    builders, so the migration changed the authoring surface and
//!    nothing else.

use scalesim::cpu::isa::{OpClass, TraceOp, NO_REG};
use scalesim::cpu::light::LightCore;
use scalesim::cpu::Trace;
use scalesim::dc::traffic::packets_by_host;
use scalesim::dc::{build_fattree, DcPacket, FatTreeCfg, Host, Switch, SwitchRole, TrafficCfg};
use scalesim::engine::{
    BuildError, Component, IfaceSpec, In, Model, ModelBuilder, Msg, Out, Payload, PortCfg, Ports,
    RunOpts, Stop, Unit, Wire,
};
use scalesim::mem::dir::DirBank;
use scalesim::mem::dram::DramChannel;
use scalesim::mem::l1::L1Cache;
use scalesim::mem::l2::L2Cache;
use scalesim::mem::{MemMsg, MemPacket};
use scalesim::noc::{Flit, MeshCfg};
use scalesim::scenario::PipeMsg;
use scalesim::systems::{build_cpu_system, CpuSystemCfg};

// ---------------------------------------------------------------------
// 1. Payload roundtrips
// ---------------------------------------------------------------------

#[test]
fn mem_packet_roundtrips_every_kind() {
    for (i, &kind) in MemMsg::ALL.iter().enumerate() {
        let p = MemPacket::new(kind, 0x40 * i as u64, (3 << 32) | 9, i as u64 + 7);
        let m = p.encode();
        assert!(m.payload.is_none(), "typed payloads never box");
        assert_eq!(MemPacket::decode(&m), p);
    }
}

#[test]
fn dc_packet_roundtrips() {
    let p = DcPacket {
        id: 123_456,
        src: 17,
        dst: 1_020,
        inject: 9_999,
    };
    let m = p.encode();
    assert_eq!(DcPacket::decode(&m), p);
}

#[test]
fn flit_roundtrips() {
    let f = Flit::new(42, 3, 15, 1_000);
    let m = f.encode();
    assert_eq!(Flit::decode(&m), f);
}

#[test]
fn pipe_msg_roundtrips() {
    let p = PipeMsg {
        seq: 5,
        acc: u64::MAX - 3,
    };
    let m = p.encode();
    let q = PipeMsg::decode(&m);
    assert_eq!((q.seq, q.acc), (p.seq, p.acc));
}

// ---------------------------------------------------------------------
// 2. Builder validation
// ---------------------------------------------------------------------

struct Nop;
impl Unit for Nop {
    fn work(&mut self, _ctx: &mut scalesim::engine::Ctx<'_>) {}
}

#[test]
fn dangling_unit_is_a_typed_error() {
    let mut mb = ModelBuilder::new();
    let _ghost = mb.reserve_unit("ghost");
    match mb.build() {
        Err(e @ BuildError::DanglingUnit { unit: 0, .. }) => {
            assert!(e.to_string().contains("ghost"));
        }
        other => panic!("expected DanglingUnit, got {other:?}"),
    }
}

#[test]
fn self_loop_is_a_typed_error() {
    let mut mb = ModelBuilder::new();
    let a = mb.reserve_unit("selfie");
    let _ = mb.link::<Msg>(a, a, PortCfg::default());
    mb.install(a, Box::new(Nop));
    match mb.build() {
        Err(e @ BuildError::SelfLoopPort { unit: 0, .. }) => {
            assert!(e.to_string().contains("itself"));
        }
        other => panic!("expected SelfLoopPort, got {other:?}"),
    }
}

#[test]
fn zero_capacity_is_a_typed_error() {
    let mut mb = ModelBuilder::new();
    let a = mb.reserve_unit("a");
    let b = mb.reserve_unit("b");
    let _ = mb.link::<Msg>(
        a,
        b,
        PortCfg {
            capacity: 1,
            out_capacity: 0,
            delay: 1,
        },
    );
    mb.install(a, Box::new(Nop));
    mb.install(b, Box::new(Nop));
    match mb.build() {
        Err(BuildError::ZeroCapacityPort { src: 0, dst: 1 }) => {}
        other => panic!("expected ZeroCapacityPort, got {other:?}"),
    }
}

#[test]
fn unconnected_iface_is_a_typed_error() {
    struct Talker;
    impl Component for Talker {
        fn name(&self) -> String {
            "talker".into()
        }
        fn outputs(&self) -> Vec<IfaceSpec> {
            vec![IfaceSpec::new("tx", PortCfg::default())]
        }
        fn build(self: Box<Self>, _p: &Ports) -> Box<dyn Unit> {
            Box::new(Nop)
        }
    }
    let mut wire = Wire::new();
    let _ = wire.add(Talker);
    match wire.build() {
        Err(e @ BuildError::UnconnectedIface { iface: "tx", .. }) => {
            let boxed: Box<dyn std::error::Error> = Box::new(e);
            assert!(boxed.to_string().contains("never connected"));
        }
        other => panic!("expected UnconnectedIface, got {other:?}"),
    }
}

#[test]
fn build_errors_propagate_through_scenario_sessions_as_strings() {
    // A bad scenario config path still yields Err, not a panic.
    let mut cfg = scalesim::util::config::Config::new();
    cfg.set("dim", "1");
    // `.err()` rather than `.unwrap_err()`: `Sim` carries closures and has
    // no Debug impl.
    let err = scalesim::engine::Sim::scenario("torus", &cfg)
        .err()
        .expect("dim=1 torus must fail to build");
    assert!(err.contains(">= 2"), "{err}");
}

// ---------------------------------------------------------------------
// 3a. Fat-tree: raw tuple construction == typed construction
// ---------------------------------------------------------------------

/// The pre-wire-layer fat-tree recipe, verbatim: raw `connect` tuples,
/// handles wrapped with `from_raw` only at the (now typed) unit
/// boundaries.
fn build_fattree_raw(cfg: &FatTreeCfg) -> (Model, scalesim::stats::counters::CounterId, u64) {
    let k = cfg.k;
    let half = k / 2;
    let hosts = cfg.hosts();
    let hosts_per_pod = half * half;
    let mut traffic = cfg.traffic;
    traffic.hosts = hosts;

    let mut mb = ModelBuilder::new();
    let delivered = mb.counter("dc.delivered");

    let mut host_units = vec![0u32; hosts as usize];
    let mut edge_units = vec![0u32; (k * half) as usize];
    let mut agg_units = vec![0u32; (k * half) as usize];
    for pod in 0..k {
        for h in 0..hosts_per_pod {
            let hid = pod * hosts_per_pod + h;
            host_units[hid as usize] = mb.reserve_unit(&format!("host{hid}"));
        }
        for e in 0..half {
            edge_units[(pod * half + e) as usize] = mb.reserve_unit(&format!("edge{pod}_{e}"));
        }
        for a in 0..half {
            agg_units[(pod * half + a) as usize] = mb.reserve_unit(&format!("agg{pod}_{a}"));
        }
    }
    let core_units: Vec<u32> = (0..half * half)
        .map(|c| mb.reserve_unit(&format!("core{c}")))
        .collect();

    let mut edges: Vec<Switch> = (0..k * half)
        .map(|i| {
            Switch::new(
                SwitchRole::Edge {
                    pod: i / half,
                    index: i % half,
                },
                k,
            )
        })
        .collect();
    let mut aggs: Vec<Switch> = (0..k * half)
        .map(|i| {
            Switch::new(
                SwitchRole::Agg {
                    pod: i / half,
                    index: i % half,
                },
                k,
            )
        })
        .collect();
    let mut cores: Vec<Switch> = (0..half * half)
        .map(|i| Switch::new(SwitchRole::Core { index: i }, k))
        .collect();

    let host_link = PortCfg::new(cfg.buffer, cfg.link_delay);
    let fabric_link = PortCfg::new(cfg.buffer, cfg.link_delay + cfg.pipeline);

    let per_host = packets_by_host(&traffic);
    for hid in 0..hosts {
        let pod = hid / hosts_per_pod;
        let e = (hid % hosts_per_pod) / half;
        let local = hid % half;
        let hu = host_units[hid as usize];
        let eu = edge_units[(pod * half + e) as usize];
        let (h2e, e_in) = mb.connect(hu, eu, host_link);
        let (e_out, h_in) = mb.connect(eu, hu, host_link);
        edges[(pod * half + e) as usize].set_port(
            local,
            In::from_raw(e_in),
            Out::from_raw(e_out),
        );
        mb.install(
            hu,
            Box::new(Host::new(
                hid,
                per_host[hid as usize].clone(),
                Out::<DcPacket>::from_raw(h2e),
                In::<DcPacket>::from_raw(h_in),
                delivered,
            )),
        );
    }
    for pod in 0..k {
        for e in 0..half {
            for a in 0..half {
                let eu = edge_units[(pod * half + e) as usize];
                let au = agg_units[(pod * half + a) as usize];
                let (e2a, a_in) = mb.connect(eu, au, fabric_link);
                let (a2e, e_in) = mb.connect(au, eu, fabric_link);
                edges[(pod * half + e) as usize].set_port(
                    half + a,
                    In::from_raw(e_in),
                    Out::from_raw(e2a),
                );
                aggs[(pod * half + a) as usize].set_port(
                    e,
                    In::from_raw(a_in),
                    Out::from_raw(a2e),
                );
            }
        }
    }
    for pod in 0..k {
        for a in 0..half {
            for j in 0..half {
                let au = agg_units[(pod * half + a) as usize];
                let c = a * half + j;
                let cu = core_units[c as usize];
                let (a2c, c_in) = mb.connect(au, cu, fabric_link);
                let (c2a, a_in) = mb.connect(cu, au, fabric_link);
                aggs[(pod * half + a) as usize].set_port(
                    half + j,
                    In::from_raw(a_in),
                    Out::from_raw(a2c),
                );
                cores[c as usize].set_port(pod, In::from_raw(c_in), Out::from_raw(c2a));
            }
        }
    }
    for (i, sw) in edges.into_iter().enumerate() {
        mb.install(edge_units[i], Box::new(sw));
    }
    for (i, sw) in aggs.into_iter().enumerate() {
        mb.install(agg_units[i], Box::new(sw));
    }
    for (i, sw) in cores.into_iter().enumerate() {
        mb.install(core_units[i], Box::new(sw));
    }
    (mb.build().unwrap(), delivered, traffic.packets)
}

#[test]
fn fattree_raw_and_typed_constructions_are_bit_identical() {
    let cfg = FatTreeCfg {
        k: 4,
        buffer: 2,
        traffic: TrafficCfg {
            seed: 7,
            hosts: 16,
            packets: 300,
            inject_window: 200,
        },
        ..Default::default()
    };
    let (mut typed, h) = build_fattree(&cfg);
    let (mut raw, delivered, packets) = build_fattree_raw(&cfg);
    assert_eq!(typed.num_units(), raw.num_units());
    assert_eq!(typed.num_ports(), raw.num_ports());
    let stop = |counter, target| Stop::CounterAtLeast {
        counter,
        target,
        max_cycles: 100_000,
    };
    let st = typed.run_serial(RunOpts::with_stop(stop(h.delivered, h.packets)).fingerprinted());
    let sr = raw.run_serial(RunOpts::with_stop(stop(delivered, packets)).fingerprinted());
    assert_eq!(st.fingerprint, sr.fingerprint, "typed wiring changed nothing");
    assert_eq!(st.cycles, sr.cycles);
    assert_eq!(
        st.counters.get("dc.delivered"),
        sr.counters.get("dc.delivered")
    );
}

// ---------------------------------------------------------------------
// 3b. CPU system: raw tuple construction == typed construction
// ---------------------------------------------------------------------

fn small_traces(cores: usize) -> Vec<Trace> {
    (0..cores as u64)
        .map(|c| Trace {
            ops: (0..50u64)
                .map(|i| {
                    if i % 3 == 0 {
                        TraceOp::new(
                            OpClass::Load,
                            1,
                            2,
                            NO_REG,
                            0x1000 + ((c * 64 + i * 8) % 4096),
                            0,
                            false,
                        )
                    } else if i % 7 == 0 {
                        TraceOp::new(OpClass::Store, NO_REG, 1, 2, 0x8000 + (i % 512), 0, false)
                    } else {
                        TraceOp::new(OpClass::Alu, 1, 1, 2, 0, 0, false)
                    }
                })
                .collect(),
        })
        .collect()
}

/// The pre-wire-layer CPU-system recipe: raw `connect` everywhere, typed
/// handles wrapped at the unit constructors. Mirrors
/// `systems::build_cpu_system` port-for-port (the mesh helper is typed
/// now, so its trunk wiring is replicated inline).
fn build_cpu_system_raw(
    traces: Vec<Trace>,
    cfg: &CpuSystemCfg,
) -> (Model, scalesim::stats::counters::CounterId, usize) {
    let cores = traces.len();
    let mut mb = ModelBuilder::new();
    let cores_done = mb.counter("cores_done");

    let mut core_ids = Vec::with_capacity(cores);
    let mut l1_ids = Vec::with_capacity(cores);
    let mut l2_ids = Vec::with_capacity(cores);
    for c in 0..cores {
        core_ids.push(mb.reserve_unit(&format!("core{c}")));
        l1_ids.push(mb.reserve_unit(&format!("l1_{c}")));
        l2_ids.push(mb.reserve_unit(&format!("l2_{c}")));
    }
    let bank_ids: Vec<u32> = (0..cfg.banks)
        .map(|b| mb.reserve_unit(&format!("l3bank{b}")))
        .collect();
    let dram_ids: Vec<u32> = (0..cfg.banks)
        .map(|b| mb.reserve_unit(&format!("dram{b}")))
        .collect();

    let nodes = cores + cfg.banks;
    let width = (nodes as f64).sqrt().ceil() as u32;
    let height = (nodes as u32).div_ceil(width);
    let mesh_cfg = MeshCfg {
        width,
        height,
        link_capacity: 4,
        link_delay: cfg.mesh_link_delay,
        local_capacity: 4,
    };
    // Raw mesh replica: routers reserved, trunk links connected in the
    // same order `Mesh::build` uses.
    use scalesim::noc::router::{Router, DIR_E, DIR_LOCAL, DIR_N, DIR_S, DIR_W};
    let n_routers = (width * height) as usize;
    let router_ids: Vec<u32> = (0..n_routers)
        .map(|i| mb.reserve_unit(&format!("router{i}")))
        .collect();
    let mut routers: Vec<Router> = (0..n_routers)
        .map(|i| Router::new(i as u32, i as u32 % width, i as u32 / width, width))
        .collect();
    let trunk = PortCfg::new(mesh_cfg.link_capacity, mesh_cfg.link_delay);
    for y in 0..height {
        for x in 0..width {
            let a = (y * width + x) as usize;
            if x + 1 < width {
                let b = a + 1;
                let (tx, rx) = mb.connect(router_ids[a], router_ids[b], trunk);
                routers[a].set_output(DIR_E, Out::from_raw(tx));
                routers[b].set_input(DIR_W, In::from_raw(rx));
                let (tx, rx) = mb.connect(router_ids[b], router_ids[a], trunk);
                routers[b].set_output(DIR_W, Out::from_raw(tx));
                routers[a].set_input(DIR_E, In::from_raw(rx));
            }
            if y + 1 < height {
                let b = a + width as usize;
                let (tx, rx) = mb.connect(router_ids[a], router_ids[b], trunk);
                routers[a].set_output(DIR_S, Out::from_raw(tx));
                routers[b].set_input(DIR_N, In::from_raw(rx));
                let (tx, rx) = mb.connect(router_ids[b], router_ids[a], trunk);
                routers[b].set_output(DIR_N, Out::from_raw(tx));
                routers[a].set_input(DIR_S, In::from_raw(rx));
            }
        }
    }
    let local = PortCfg::new(mesh_cfg.local_capacity, 1);
    let attach_raw = |mb: &mut ModelBuilder,
                      routers: &mut [Router],
                      node: u32,
                      unit: u32| {
        let rid = router_ids[node as usize];
        let (to_net, router_in) = mb.connect(unit, rid, local);
        let (router_out, from_net) = mb.connect(rid, unit, local);
        routers[node as usize].set_input(DIR_LOCAL, In::from_raw(router_in));
        routers[node as usize].set_output(DIR_LOCAL, Out::from_raw(router_out));
        (to_net, from_net)
    };

    let core_nodes: Vec<u32> = (0..cores as u32).collect();
    let bank_nodes: Vec<u32> = (0..cfg.banks as u32).map(|b| cores as u32 + b).collect();

    for c in 0..cores {
        let (core_to_l1, l1_from_core) =
            mb.connect(core_ids[c], l1_ids[c], PortCfg::new(4, cfg.l1_delay));
        let (l1_to_core, core_from_l1) =
            mb.connect(l1_ids[c], core_ids[c], PortCfg::new(4, cfg.l1_delay));
        let (l1_to_l2, l2_from_l1) =
            mb.connect(l1_ids[c], l2_ids[c], PortCfg::new(4, cfg.l2_delay));
        let (l2_to_l1, l1_from_l2) =
            mb.connect(l2_ids[c], l1_ids[c], PortCfg::new(4, cfg.l2_delay));
        let (l2_to_net, l2_from_net) = attach_raw(&mut mb, &mut routers, core_nodes[c], l2_ids[c]);

        let mut core = LightCore::new(
            c as u32,
            traces[c].ops.clone(),
            Out::<MemPacket>::from_raw(core_to_l1),
            In::<MemPacket>::from_raw(core_from_l1),
            cores_done,
        );
        core.mul_latency = cfg.mul_latency;
        mb.install(core_ids[c], Box::new(core));
        mb.install(
            l1_ids[c],
            Box::new(L1Cache::new(
                c as u32,
                cfg.l1,
                In::from_raw(l1_from_core),
                Out::from_raw(l1_to_core),
                Out::from_raw(l1_to_l2),
                In::from_raw(l1_from_l2),
            )),
        );
        mb.install(
            l2_ids[c],
            Box::new(L2Cache::new(
                c as u32,
                core_nodes[c],
                bank_nodes.clone(),
                cfg.l2,
                In::from_raw(l2_from_l1),
                Out::from_raw(l2_to_l1),
                Out::from_raw(l2_to_net),
                In::from_raw(l2_from_net),
            )),
        );
    }
    for b in 0..cfg.banks {
        let (bank_to_net, bank_from_net) =
            attach_raw(&mut mb, &mut routers, bank_nodes[b], bank_ids[b]);
        let (bank_to_dram, dram_from_bank) =
            mb.connect(bank_ids[b], dram_ids[b], PortCfg::new(8, 1));
        let (dram_to_bank, bank_from_dram) =
            mb.connect(dram_ids[b], bank_ids[b], PortCfg::new(8, 1));
        mb.install(
            bank_ids[b],
            Box::new(DirBank::new(
                b as u32,
                bank_nodes[b],
                core_nodes.clone(),
                cfg.l3_bank,
                In::from_raw(bank_from_net),
                Out::from_raw(bank_to_net),
                Out::from_raw(bank_to_dram),
                In::from_raw(bank_from_dram),
            )),
        );
        mb.install(
            dram_ids[b],
            Box::new(DramChannel::new(
                b as u32,
                In::from_raw(dram_from_bank),
                Out::from_raw(dram_to_bank),
                cfg.dram_latency,
                1,
            )),
        );
    }
    for (i, r) in routers.into_iter().enumerate() {
        mb.install(router_ids[i], Box::new(r));
    }
    (mb.build().unwrap(), cores_done, cores)
}

#[test]
fn cpu_system_raw_and_typed_constructions_are_bit_identical() {
    let cfg = CpuSystemCfg::default();
    let (mut typed, h) = build_cpu_system(small_traces(2), &cfg);
    let (mut raw, cores_done, cores) = build_cpu_system_raw(small_traces(2), &cfg);
    assert_eq!(typed.num_units(), raw.num_units());
    assert_eq!(typed.num_ports(), raw.num_ports());
    let st = typed.run_serial(
        RunOpts::with_stop(Stop::CounterAtLeast {
            counter: h.cores_done,
            target: 2,
            max_cycles: 200_000,
        })
        .fingerprinted(),
    );
    let sr = raw.run_serial(
        RunOpts::with_stop(Stop::CounterAtLeast {
            counter: cores_done,
            target: cores as u64,
            max_cycles: 200_000,
        })
        .fingerprinted(),
    );
    assert_eq!(st.fingerprint, sr.fingerprint, "typed wiring changed nothing");
    assert_eq!(st.cycles, sr.cycles);
    assert_eq!(
        st.counters.get("core.retired"),
        sr.counters.get("core.retired")
    );
}
